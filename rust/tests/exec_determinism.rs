//! Determinism contracts of the two execution accelerators:
//!
//! * the **parallel sweep executor** must be bit-identical to running
//!   the same simulations serially on one thread — the contract that
//!   lets the figure harness fan the paper's sweeps across cores
//!   without changing a single plotted value;
//! * the **event-horizon skip engine** must be bit-identical to the
//!   dense cycle-by-cycle loop — the contract that lets it fast-forward
//!   quiescent windows (and lets the sweep cache stay mode-agnostic:
//!   a cached report is valid under either mode).

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::{SimJob, StreamJob, SweepExec};
use amoeba_gpu::runtime::fleet::{serve_fleet, ChipHealth, FleetConfig};
use amoeba_gpu::sim::fault::{FaultEvent, FaultKind, FaultTrace};
use amoeba_gpu::sim::gpu::{
    run_benchmark_faulted_dense, run_benchmark_faulted_jobs, run_benchmark_resume,
    run_benchmark_seeded, run_benchmark_seeded_auto, run_benchmark_seeded_dense,
    run_benchmark_seeded_jobs, run_benchmark_snapshot, serve_streams_auto, serve_streams_dense,
    serve_streams_faulted_dense, serve_streams_jobs, serve_streams_resume, serve_streams_snapshot,
    PartitionPolicy, SimReport, StreamReport,
};
use amoeba_gpu::workload::{bench, shrink_streams, traffic_trace, KernelStream, Priority};

fn grid() -> (SystemConfig, Vec<SimJob>) {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let benches = ["CP", "BFS", "RAY"];
    let schemes = [Scheme::Baseline, Scheme::WarpRegroup, Scheme::Hetero];
    let mut jobs = Vec::new();
    for name in benches {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for s in schemes {
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, 0xD37));
        }
    }
    (cfg, jobs)
}

/// >= 3 benches x 2 schemes: every counter the figures plot must match
/// the serial path exactly, including the predictor decisions.
#[test]
fn parallel_executor_matches_serial_bit_for_bit() {
    let (_cfg, jobs) = grid();
    let exec = SweepExec::new(4);
    let parallel = exec.run_batch(jobs.clone());
    assert_eq!(parallel.len(), jobs.len());

    for (job, pr) in jobs.iter().zip(&parallel) {
        let sr = run_benchmark_seeded(&job.cfg, &job.profile, job.scheme, job.seed).unwrap();
        let label = format!("{} under {}", job.profile.name, job.scheme);
        assert_eq!(sr.cycles, pr.cycles, "{label}: cycles");
        assert_eq!(sr.sm.thread_insns, pr.sm.thread_insns, "{label}: thread insns");
        assert_eq!(sr.sm.warp_insns, pr.sm.warp_insns, "{label}: warp insns");
        assert_eq!(sr.sm.l1d_accesses, pr.sm.l1d_accesses, "{label}: l1d accesses");
        assert_eq!(sr.sm.l1d_misses, pr.sm.l1d_misses, "{label}: l1d misses");
        assert_eq!(sr.sm.noc_flits, pr.sm.noc_flits, "{label}: noc flits");
        assert_eq!(sr.sm.mshr_merges, pr.sm.mshr_merges, "{label}: mshr merges");
        assert_eq!(sr.chip.dram_reads, pr.chip.dram_reads, "{label}: dram reads");
        assert_eq!(sr.chip.l2_misses, pr.chip.l2_misses, "{label}: l2 misses");
        assert_eq!(
            sr.ipc().to_bits(),
            pr.ipc().to_bits(),
            "{label}: IPC must be bit-identical"
        );
        // Predictor decisions (probability compared at the bit level).
        assert_eq!(sr.decisions.len(), pr.decisions.len(), "{label}: decision count");
        for (a, b) in sr.decisions.iter().zip(&pr.decisions) {
            assert_eq!(a.scale_up, b.scale_up, "{label}: decision");
            assert_eq!(a.cluster, b.cluster, "{label}: decision cluster");
            assert_eq!(
                a.probability.to_bits(),
                b.probability.to_bits(),
                "{label}: decision probability"
            );
        }
        // The heterogeneous scheme decides per cluster per kernel; the
        // per-cluster log must survive the parallel path intact.
        if job.scheme == Scheme::Hetero {
            let n_clusters = job.cfg.num_sms / 2;
            assert_eq!(
                pr.decisions.len(),
                n_clusters * job.profile.num_kernels as usize,
                "{label}: one decision per cluster per kernel"
            );
            for (i, d) in pr.decisions.iter().enumerate() {
                assert_eq!(d.cluster, Some((i % n_clusters) as u32), "{label}: cluster ids");
            }
        }
    }
}

/// Field-complete bitwise comparison of two reports. `SimReport`'s
/// derived `PartialEq` covers every counter/decision/phase/sample by
/// value; the float fields are additionally pinned at the bit level.
fn assert_reports_identical(a: &SimReport, b: &SimReport, label: &str) {
    assert_eq!(a.cycles, b.cycles, "{label}: cycles");
    assert_eq!(a.sm, b.sm, "{label}: SmStats");
    assert_eq!(a.chip, b.chip, "{label}: ChipStats");
    assert_eq!(a.phases, b.phases, "{label}: phase trace");
    assert_eq!(a.decisions.len(), b.decisions.len(), "{label}: decision count");
    for (i, (x, y)) in a.decisions.iter().zip(&b.decisions).enumerate() {
        assert_eq!(x.scale_up, y.scale_up, "{label}: decision {i}");
        assert_eq!(x.cluster, y.cluster, "{label}: decision {i} cluster");
        assert_eq!(
            x.probability.to_bits(),
            y.probability.to_bits(),
            "{label}: decision {i} probability"
        );
    }
    assert_eq!(a.samples.len(), b.samples.len(), "{label}: sample count");
    for (i, (x, y)) in a.samples.iter().zip(&b.samples).enumerate() {
        for (j, (fa, fb)) in x.features.iter().zip(&y.features).enumerate() {
            assert_eq!(fa.to_bits(), fb.to_bits(), "{label}: sample {i} feature {j}");
        }
    }
    assert_eq!(a, b, "{label}: full report");
}

/// The event-horizon engine vs the dense reference loop: bit-identical
/// `SimReport`s for **every** scheme, including the heterogeneous
/// mixed-layout path (per-cluster decisions, `DynSplit` timers keyed on
/// absolute `now`).
#[test]
fn cycle_skip_matches_dense_across_all_schemes() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    for name in ["RAY", "SM"] {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in Scheme::ALL {
            let label = format!("{name} under {scheme}");
            let dense = run_benchmark_seeded_dense(&cfg, &p, scheme, 0xD37, true).unwrap();
            let skip = run_benchmark_seeded_dense(&cfg, &p, scheme, 0xD37, false).unwrap();
            assert_eq!(dense.chip.kernels_completed, 1, "{label}: completes");
            assert_reports_identical(&dense, &skip, &label);
        }
    }
}

/// Same contract on a DynSplit-active run: a lowered split threshold and
/// a short check period force fused clusters through split/rebalance/
/// re-fuse transitions, whose timers (`last_rebalance`, `split_check_at`)
/// use absolute `now` arithmetic the skip engine must preserve exactly.
#[test]
fn cycle_skip_matches_dense_with_active_dynamic_splits() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    cfg.split_threshold = 0.05;
    cfg.split_check_period = 128;
    cfg.rebalance_period = 256;
    let mut p = bench("RAY").unwrap(); // divergence-heavy: triggers splits
    p.num_ctas = 10;
    p.insns_per_thread = 100;
    p.num_kernels = 2; // cross a kernel boundary with live split state
    for scheme in [Scheme::DirectSplit, Scheme::WarpRegroup, Scheme::Hetero] {
        let label = format!("split-active RAY under {scheme}");
        let dense = run_benchmark_seeded_dense(&cfg, &p, scheme, 0xA7, true).unwrap();
        let skip = run_benchmark_seeded_dense(&cfg, &p, scheme, 0xA7, false).unwrap();
        assert_reports_identical(&dense, &skip, &label);
    }
}

/// Multi-seed sweep of the memory-divergent profiles (where the skip
/// engine actually skips): the contract must hold on exactly the runs
/// it accelerates most.
#[test]
fn cycle_skip_matches_dense_on_memory_bound_profiles() {
    let cfg = SystemConfig::tiny();
    for name in ["BFS", "MUM"] {
        let mut p = bench(name).unwrap();
        p.num_ctas = 6;
        p.insns_per_thread = 90;
        p.num_kernels = 1;
        for seed in [1u64, 2, 3] {
            let label = format!("{name} seed {seed}");
            let dense = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, seed, true).unwrap();
            let skip = run_benchmark_seeded_dense(&cfg, &p, Scheme::Baseline, seed, false).unwrap();
            assert_reports_identical(&dense, &skip, &label);
        }
    }
}

/// The sweep executor's memo cache is mode-agnostic: whatever mode the
/// executor ran under (the `AMOEBA_DENSE` environment), its cached
/// reports must equal the dense reference bit for bit — so a report
/// computed in one mode can be served to a consumer expecting the other.
#[test]
fn sweep_cache_entries_match_the_dense_reference() {
    let (_cfg, jobs) = grid();
    let exec = SweepExec::new(4);
    let out = exec.run_batch(jobs.clone());
    for (job, r) in jobs.iter().zip(&out) {
        let reference =
            run_benchmark_seeded_dense(&job.cfg, &job.profile, job.scheme, job.seed, true).unwrap();
        let label = format!("cached {} under {}", job.profile.name, job.scheme);
        assert_reports_identical(&reference, r, &label);
    }
}

/// Multi-tenant server trace for the stream determinism contracts: a
/// heterogeneous (per-cluster-decision) tenant, a warp-regrouping tenant
/// whose lowered thresholds keep a DynSplit active, and a compute-dense
/// baseline tenant — on one chip with interleaved arrivals.
fn stream_grid() -> (SystemConfig, Vec<KernelStream>) {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 8; // 4 clusters for 3 tenants
    cfg.num_mcs = 4;
    cfg.max_cycles = 1_500_000;
    // DynSplit-active: low threshold, short check/rebalance periods.
    cfg.split_threshold = 0.05;
    cfg.split_check_period = 128;
    cfg.rebalance_period = 256;
    let tenants = [
        (bench("BFS").unwrap(), Scheme::Hetero),
        (bench("RAY").unwrap(), Scheme::WarpRegroup),
        (bench("CP").unwrap(), Scheme::Baseline),
    ];
    let mut streams = traffic_trace(&tenants, 2, 5_000, 0xD37);
    shrink_streams(&mut streams, 6, 80);
    (cfg, streams)
}

/// Field-complete bitwise comparison of two stream reports: the derived
/// `PartialEq` covers every tenant report, launch record, phase sample
/// and placement ledger; per-tenant decision probabilities and metric
/// features are additionally pinned at the bit level.
fn assert_stream_reports_identical(a: &StreamReport, b: &StreamReport, label: &str) {
    assert_eq!(a.cycles, b.cycles, "{label}: total cycles");
    assert_eq!(a.sm, b.sm, "{label}: chip SmStats");
    assert_eq!(a.chip, b.chip, "{label}: chip ChipStats");
    assert_eq!(a.launches, b.launches, "{label}: launch records");
    assert_eq!(a.phases, b.phases, "{label}: phase trace");
    assert_eq!(a.ctas_by_cluster, b.ctas_by_cluster, "{label}: placement ledger");
    assert_eq!(a.tenants.len(), b.tenants.len(), "{label}: tenant count");
    for (ti, (x, y)) in a.tenants.iter().zip(&b.tenants).enumerate() {
        assert_reports_identical(x, y, &format!("{label}: tenant {ti}"));
    }
    assert_eq!(a, b, "{label}: full stream report");
}

/// The event-horizon engine vs the dense loop on concurrent multi-kernel
/// streams: bit-identical `StreamReport`s under both partition policies,
/// with a mixed Hetero layout and an active DynSplit in one tenant.
#[test]
fn stream_cycle_skip_matches_dense() {
    let (cfg, streams) = stream_grid();
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let label = format!("streams under {policy}");
        let dense = serve_streams_dense(&cfg, &streams, policy, true).unwrap();
        let skip = serve_streams_dense(&cfg, &streams, policy, false).unwrap();
        assert!(
            dense.launches.iter().all(|l| l.finish != u64::MAX),
            "{label}: all launches served"
        );
        // The Hetero tenant must actually have exercised the per-cluster
        // path, or this test pins nothing interesting.
        assert!(
            dense.tenants[0].decisions.iter().all(|d| d.cluster.is_some())
                && !dense.tenants[0].decisions.is_empty(),
            "{label}: hetero tenant decided per cluster"
        );
        assert_stream_reports_identical(&dense, &skip, &label);
    }
}

/// The active-set engine's home regime: a *partially* busy chip — one
/// hot memory-divergent tenant keeps issuing while every other tenant
/// finished long ago, so the whole-chip quiescence skip rarely fires
/// and per-component parking carries the win. The per-cluster
/// sleep/wake and the lazy accounting replay must stay bit-identical
/// to the dense loop here too.
#[test]
fn stream_partial_quiescence_matches_dense() {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 8; // 4 clusters, one mostly-idle after the CP tenants finish
    cfg.num_mcs = 4;
    cfg.max_cycles = 1_500_000;
    let mut hot = bench("BFS").unwrap();
    hot.num_ctas = 8;
    hot.insns_per_thread = 80;
    hot.num_kernels = 3;
    let hot = KernelStream::back_to_back("hot:BFS", hot, Scheme::Baseline, 0xB0F5);
    let mut idle = bench("CP").unwrap();
    idle.num_ctas = 2;
    idle.insns_per_thread = 20;
    idle.num_kernels = 1;
    let streams = vec![
        hot,
        KernelStream::back_to_back("idle0:CP", idle.clone(), Scheme::Baseline, 0xA1),
        KernelStream::back_to_back("idle1:CP", idle, Scheme::Baseline, 0xA2),
    ];
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let label = format!("one-hot-tenant under {policy}");
        let dense = serve_streams_dense(&cfg, &streams, policy, true).unwrap();
        let active = serve_streams_dense(&cfg, &streams, policy, false).unwrap();
        assert!(dense.launches.iter().all(|l| l.finish != u64::MAX), "{label}: served");
        assert_stream_reports_identical(&dense, &active, &label);
    }
}

/// A stream mix that forces a CTA-boundary preemption: a High-priority
/// tenant arrives mid-run while a Low-priority tenant is mid-kernel on
/// more than its fair share of clusters (the recipe the gpu-level
/// preemption test pins in detail).
fn preemption_grid() -> (SystemConfig, Vec<KernelStream>) {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 8; // 4 clusters for 3 tenants
    cfg.num_mcs = 4;
    cfg.max_cycles = 1_500_000;
    let mut p0 = bench("CP").unwrap();
    p0.num_ctas = 4;
    p0.insns_per_thread = 40;
    let mut t0 = KernelStream::back_to_back("t0:CP", p0.clone(), Scheme::Baseline, 0xF01);
    t0.launches.truncate(1);
    t0.launches[0].arrival = 5_000;
    t0.priority = Priority::High;
    let mut p1 = p0.clone();
    p1.insns_per_thread = 300; // still mid-kernel when the High tenant arrives
    let mut t1 = KernelStream::back_to_back("t1:CP", p1, Scheme::Baseline, 0xF02);
    t1.launches.truncate(1);
    let mut p2 = bench("BFS").unwrap();
    p2.num_ctas = 16;
    p2.insns_per_thread = 300;
    let mut t2 = KernelStream::back_to_back("t2:BFS", p2, Scheme::Baseline, 0xF03);
    t2.launches.truncate(1);
    t2.priority = Priority::Low;
    (cfg, vec![t0, t1, t2])
}

/// Preemption-active skip vs dense: requeueing a victim's resident CTAs
/// and freezing the stolen cluster must not break the event-horizon
/// contract — both modes produce the identical report, preemptions
/// included.
#[test]
fn preemption_cycle_skip_matches_dense() {
    let (cfg, streams) = preemption_grid();
    let dense = serve_streams_dense(&cfg, &streams, PartitionPolicy::Adaptive, true).unwrap();
    let skip = serve_streams_dense(&cfg, &streams, PartitionPolicy::Adaptive, false).unwrap();
    assert!(dense.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
    assert!(dense.chip.preemptions >= 1, "the mix must actually preempt, or this pins nothing");
    assert!(dense.chip.ctas_preempted >= 1, "the victim had resident CTAs");
    assert_stream_reports_identical(&dense, &skip, "preemption-active streams");
}

/// Preemption-active parallel vs serial executor fan-out, plus the
/// memo-purity contract on re-run.
#[test]
fn preemption_sweep_parallel_matches_serial() {
    let (cfg, streams) = preemption_grid();
    let jobs =
        vec![StreamJob::new(cfg, streams, PartitionPolicy::Adaptive)];
    let par = SweepExec::new(4);
    let ser = SweepExec::serial();
    let a = par.run_stream_batch(jobs.clone());
    let b = ser.run_stream_batch(jobs.clone());
    assert!(a[0].chip.preemptions >= 1, "the mix must actually preempt");
    assert_stream_reports_identical(&a[0], &b[0], "preemption-active sweep");
    let (_, misses_before) = par.cache_stats();
    let again = par.run_stream_batch(jobs);
    let (_, misses_after) = par.cache_stats();
    assert_eq!(misses_before, misses_after, "re-running the preemption batch must not simulate");
    assert!(std::sync::Arc::ptr_eq(&a[0], &again[0]), "cached Arc must be returned");
}

/// Stream sweeps through the executor: parallel fan-out must equal the
/// serial path bit for bit, and re-running a batch must be pure cache
/// hits (the same contracts the single-application sweep obeys).
#[test]
fn stream_sweep_parallel_matches_serial() {
    let (cfg, streams) = stream_grid();
    let jobs: Vec<StreamJob> = [PartitionPolicy::Static, PartitionPolicy::Adaptive]
        .into_iter()
        .map(|p| StreamJob::new(cfg.clone(), streams.clone(), p))
        .collect();
    let par = SweepExec::new(4);
    let ser = SweepExec::serial();
    let a = par.run_stream_batch(jobs.clone());
    let b = ser.run_stream_batch(jobs.clone());
    for ((x, y), job) in a.iter().zip(&b).zip(&jobs) {
        assert_stream_reports_identical(x, y, &format!("stream sweep under {}", job.policy));
    }
    let (_, misses_before) = par.cache_stats();
    let again = par.run_stream_batch(jobs);
    let (_, misses_after) = par.cache_stats();
    assert_eq!(misses_before, misses_after, "re-running the stream batch must not simulate");
    for (x, y) in a.iter().zip(&again) {
        assert!(std::sync::Arc::ptr_eq(x, y), "cached Arc must be returned");
    }
}

/// A fault trace touching every fault kind — NoC degrade, MC stall, a
/// half-SM death mid-run and a whole-cluster death — staggered across
/// the run's lifetime.
fn mixed_fault_trace() -> FaultTrace {
    FaultTrace::new(vec![
        FaultEvent { cycle: 200, kind: FaultKind::NocDegrade { penalty: 1 } },
        FaultEvent { cycle: 400, kind: FaultKind::McStall { mc: 0, cycles: 600 } },
        FaultEvent { cycle: 900, kind: FaultKind::HalfSm { cluster: 1, half: 0 } },
        FaultEvent { cycle: 1_500, kind: FaultKind::Cluster { cluster: 0 } },
    ])
}

/// Fault injection vs the dense reference loop: injection happens on
/// live ticks (the skip engine's fast-forward caps clamp to the next
/// fault cycle, and injection wakes its target per the active-set
/// contract), so a faulted run must stay bit-identical between modes —
/// the same contract the healthy path obeys.
#[test]
fn faulted_cycle_skip_matches_dense() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let trace = mixed_fault_trace();
    for name in ["BFS", "RAY"] {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in [Scheme::Baseline, Scheme::ScaleUp, Scheme::WarpRegroup, Scheme::Hetero] {
            let label = format!("faulted {name} under {scheme}");
            let dense = run_benchmark_faulted_dense(&cfg, &p, scheme, 0xD37, true, &trace).unwrap();
            let skip = run_benchmark_faulted_dense(&cfg, &p, scheme, 0xD37, false, &trace).unwrap();
            assert_eq!(
                dense.chip.faults_injected,
                trace.len() as u64,
                "{label}: every fault lands"
            );
            assert_reports_identical(&dense, &skip, &label);
        }
    }
}

/// The same mode-equivalence contract on a faulted multi-tenant run:
/// cluster retirement requeues one tenant's CTAs and the forced split
/// reshapes the layout while other tenants keep serving — all of it
/// bit-identical between the dense and active-set loops.
#[test]
fn faulted_stream_cycle_skip_matches_dense() {
    let (cfg, streams) = stream_grid();
    let trace = mixed_fault_trace();
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let label = format!("faulted streams under {policy}");
        let dense = serve_streams_faulted_dense(&cfg, &streams, policy, true, &trace).unwrap();
        let skip = serve_streams_faulted_dense(&cfg, &streams, policy, false, &trace).unwrap();
        assert_eq!(dense.chip.faults_injected, trace.len() as u64, "{label}: faults land");
        assert!(dense.chip.clusters_retired >= 1, "{label}: cluster 0 retires");
        assert_stream_reports_identical(&dense, &skip, &label);
    }
}

/// Faulted jobs through the sweep executor: parallel fan-out equals the
/// serial path bit for bit, and the fault trace is part of the memo key
/// (a faulted job never shadows the healthy run's cache entry).
#[test]
fn faulted_sweep_parallel_matches_serial() {
    let (_cfg, jobs) = grid();
    let trace = mixed_fault_trace();
    let jobs: Vec<SimJob> = jobs.into_iter().map(|j| j.with_fault(trace.clone())).collect();
    let par = SweepExec::new(4);
    let ser = SweepExec::serial();
    let a = par.run_batch(jobs.clone());
    let b = ser.run_batch(jobs.clone());
    for ((x, y), job) in a.iter().zip(&b).zip(&jobs) {
        let label = format!("faulted sweep {} under {}", job.profile.name, job.scheme);
        assert_eq!(x.chip.faults_injected, trace.len() as u64, "{label}: faults land");
        assert_reports_identical(x, y, &label);
    }
    // Healthy runs of the same grid occupy distinct cache slots.
    let healthy: Vec<SimJob> =
        jobs.iter().map(|j| j.clone().with_fault(FaultTrace::default())).collect();
    let h = par.run_batch(healthy);
    for (x, y) in h.iter().zip(&a) {
        assert_eq!(x.chip.faults_injected, 0, "healthy run is genuinely healthy");
        assert_ne!(x.chip.faults_injected, y.chip.faults_injected);
    }
}

/// Checkpoint/restore of a single-application run: capturing at an
/// arbitrary cycle and resuming on a fresh machine must reproduce the
/// uninterrupted report bit for bit — in both execution modes, across
/// modes (a dense-captured checkpoint resumed under the skip engine and
/// vice versa), and the checkpoints the two modes capture at the same
/// cycle must be byte-identical (parking is pure wall-clock policy, so
/// the canonical all-awake capture erases it).
#[test]
fn kernel_checkpoint_restore_is_bit_identical() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let mut p = bench("BFS").unwrap();
    p.num_ctas = 8;
    p.insns_per_thread = 80;
    p.num_kernels = 2; // cross a kernel boundary with checkpoints in play
    for scheme in [Scheme::Baseline, Scheme::Hetero] {
        // A capture armed past the end never fires and never perturbs:
        // this run doubles as the uninterrupted reference.
        let (full, none) =
            run_benchmark_snapshot(&cfg, &p, scheme, 0xD37, false, u64::MAX, None).unwrap();
        assert!(none.is_none(), "armed-past-the-end snapshot must not fire");
        let end = full.cycles;
        // Adversarial capture points: the very first loop top, inside
        // the profiling window, mid-run (Drain/Quiesce under Hetero),
        // and the closing cycles.
        for at in [1, end / 8, end / 2, (end * 7) / 8, end.saturating_sub(2)] {
            for dense in [false, true] {
                let label = format!("{scheme} snap@{at} dense={dense}");
                let (rep, cp) =
                    run_benchmark_snapshot(&cfg, &p, scheme, 0xD37, dense, at, None).unwrap();
                assert_reports_identical(&rep, &full, &format!("{label}: capture-side run"));
                let cp = cp.expect("snapshot inside the run must fire");
                let resumed = run_benchmark_resume(&cfg, &p, scheme, 0xD37, dense, &cp).unwrap();
                assert_reports_identical(&resumed, &full, &format!("{label}: resumed run"));
                let crossed = run_benchmark_resume(&cfg, &p, scheme, 0xD37, !dense, &cp).unwrap();
                assert_reports_identical(&crossed, &full, &format!("{label}: cross-mode resume"));
            }
            // Dense and active capture the same machine, byte for byte.
            let (_, ca) =
                run_benchmark_snapshot(&cfg, &p, scheme, 0xD37, false, at, None).unwrap();
            let (_, cd) = run_benchmark_snapshot(&cfg, &p, scheme, 0xD37, true, at, None).unwrap();
            let (ca, cd) = (ca.unwrap(), cd.unwrap());
            assert!(
                ca.state_diff(&cd).is_empty(),
                "snap@{at} under {scheme}: state differs across modes: {:?}",
                ca.state_diff(&cd)
            );
            assert_eq!(ca.to_bytes(), cd.to_bytes(), "snap@{at} under {scheme}: bytes differ");
        }
    }
}

/// The same contract on a faulted run: checkpoints taken between fault
/// events (mid-MC-stall, after a half-SM death, after a whole-cluster
/// retirement) carry the pending-fault cursor, so the resumed run still
/// injects exactly the remaining faults and lands on the reference
/// report bit for bit.
#[test]
fn faulted_checkpoint_restore_is_bit_identical() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let trace = mixed_fault_trace();
    let mut p = bench("BFS").unwrap();
    p.num_ctas = 8;
    p.insns_per_thread = 80;
    p.num_kernels = 1;
    let (full, _) =
        run_benchmark_snapshot(&cfg, &p, Scheme::Baseline, 0xD37, false, u64::MAX, Some(&trace))
            .unwrap();
    assert_eq!(full.chip.faults_injected, trace.len() as u64, "every fault lands");
    // 300 = before any fault beyond the NoC degrade; 500 = inside the MC
    // stall window; 1_000 = after the half-SM death; 1_600 = after the
    // whole-cluster retirement.
    for at in [300u64, 500, 1_000, 1_600] {
        if at >= full.cycles.saturating_sub(1) {
            continue;
        }
        for dense in [false, true] {
            let label = format!("faulted snap@{at} dense={dense}");
            let (rep, cp) =
                run_benchmark_snapshot(&cfg, &p, Scheme::Baseline, 0xD37, dense, at, Some(&trace))
                    .unwrap();
            assert_reports_identical(&rep, &full, &format!("{label}: capture-side run"));
            let cp = cp.expect("snapshot inside the run must fire");
            let resumed =
                run_benchmark_resume(&cfg, &p, Scheme::Baseline, 0xD37, dense, &cp).unwrap();
            assert_reports_identical(&resumed, &full, &format!("{label}: resumed run"));
        }
    }
}

/// Checkpoint/restore of a concurrent multi-tenant run: the stream grid
/// keeps a Hetero tenant (per-cluster Drain/Quiesce transitions) and a
/// DynSplit-active tenant live, so mid-run captures land inside tenant
/// phase machines — and the resumed run must still be bit-identical
/// under both partition policies and both execution modes.
#[test]
fn stream_checkpoint_restore_is_bit_identical() {
    let (cfg, streams) = stream_grid();
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let (full, none) =
            serve_streams_snapshot(&cfg, &streams, policy, false, u64::MAX, None).unwrap();
        assert!(none.is_none(), "armed-past-the-end snapshot must not fire");
        assert!(full.launches.iter().all(|l| l.finish != u64::MAX), "all launches served");
        let end = full.cycles;
        for at in [1, end / 4, end / 2, (end * 3) / 4] {
            for dense in [false, true] {
                let label = format!("streams {policy} snap@{at} dense={dense}");
                let (rep, cp) =
                    serve_streams_snapshot(&cfg, &streams, policy, dense, at, None).unwrap();
                assert_stream_reports_identical(&rep, &full, &format!("{label}: capture side"));
                let cp = cp.expect("snapshot inside the run must fire");
                let resumed = serve_streams_resume(&cfg, &streams, policy, dense, &cp).unwrap();
                assert_stream_reports_identical(&resumed, &full, &format!("{label}: resumed"));
            }
            let (_, ca) = serve_streams_snapshot(&cfg, &streams, policy, false, at, None).unwrap();
            let (_, cd) = serve_streams_snapshot(&cfg, &streams, policy, true, at, None).unwrap();
            assert_eq!(
                ca.unwrap().to_bytes(),
                cd.unwrap().to_bytes(),
                "streams {policy} snap@{at}: checkpoint bytes differ across modes"
            );
        }
    }
}

/// Restore across a CTA-boundary preemption: capture just before the
/// High-priority tenant arrives, inside the preemption window (victim
/// CTAs requeued, stolen cluster frozen), and after — resuming from any
/// of them reproduces the uninterrupted report, preemption counters
/// included.
#[test]
fn preemption_checkpoint_restore_is_bit_identical() {
    let (cfg, streams) = preemption_grid();
    let policy = PartitionPolicy::Adaptive;
    let (full, _) =
        serve_streams_snapshot(&cfg, &streams, policy, false, u64::MAX, None).unwrap();
    assert!(full.chip.preemptions >= 1, "the mix must actually preempt, or this pins nothing");
    assert!(full.cycles > 5_200, "the run must outlive the preemption window");
    // The High tenant arrives at 5_000; the preemption lands shortly after.
    for at in [4_999u64, 5_001, 5_050, 5_200] {
        for dense in [false, true] {
            let label = format!("preemption snap@{at} dense={dense}");
            let (rep, cp) =
                serve_streams_snapshot(&cfg, &streams, policy, dense, at, None).unwrap();
            assert_stream_reports_identical(&rep, &full, &format!("{label}: capture side"));
            let cp = cp.expect("snapshot inside the run must fire");
            let resumed = serve_streams_resume(&cfg, &streams, policy, dense, &cp).unwrap();
            assert_stream_reports_identical(&resumed, &full, &format!("{label}: resumed"));
        }
    }
}

/// Restore refuses mismatched worlds instead of silently diverging: a
/// kernel checkpoint fed to the stream entry point, a wrong-seed resume,
/// and a wrong-shape machine are all structured errors.
#[test]
fn checkpoint_restore_rejects_mismatches() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let mut p = bench("CP").unwrap();
    p.num_ctas = 8;
    p.insns_per_thread = 80;
    p.num_kernels = 1;
    let (_, cp) =
        run_benchmark_snapshot(&cfg, &p, Scheme::Baseline, 0xD37, false, 50, None).unwrap();
    let cp = cp.unwrap();
    // Wrong mode: a kernel checkpoint is not a stream checkpoint.
    let (_, streams) = stream_grid();
    assert!(serve_streams_resume(&cfg, &streams, PartitionPolicy::Static, false, &cp).is_err());
    // Wrong seed: the workload generator would not replay the same trace.
    assert!(run_benchmark_resume(&cfg, &p, Scheme::Baseline, 0xD38, false, &cp).is_err());
    // Wrong scheme: the controller would re-decide differently.
    assert!(run_benchmark_resume(&cfg, &p, Scheme::ScaleUp, 0xD37, false, &cp).is_err());
    // Wrong machine shape.
    let mut big = cfg.clone();
    big.num_sms *= 2;
    assert!(run_benchmark_resume(&big, &p, Scheme::Baseline, 0xD37, false, &cp).is_err());
}

/// Running the same batch twice must be pure cache hits, and a serial
/// (1-thread) executor must agree with a parallel one.
#[test]
fn serial_and_parallel_executors_agree() {
    let (_cfg, jobs) = grid();
    let par = SweepExec::new(4);
    let ser = SweepExec::serial();
    let a = par.run_batch(jobs.clone());
    let b = ser.run_batch(jobs.clone());
    for ((x, y), job) in a.iter().zip(&b).zip(&jobs) {
        assert_eq!(x.cycles, y.cycles, "{} under {}", job.profile.name, job.scheme);
        assert_eq!(x.sm.thread_insns, y.sm.thread_insns);
        assert_eq!(x.ipc().to_bits(), y.ipc().to_bits());
    }

    let (_, misses_before) = par.cache_stats();
    let again = par.run_batch(jobs.clone());
    let (_, misses_after) = par.cache_stats();
    assert_eq!(misses_before, misses_after, "re-running the batch must not simulate");
    for (x, y) in a.iter().zip(&again) {
        assert!(std::sync::Arc::ptr_eq(x, y), "cached Arc must be returned");
    }
}

// ----------------------------------------------------------------------
// Intra-simulation parallel ticking (`AMOEBA_TICK_JOBS`): fanning the
// live cluster set across worker threads within one cycle is pure
// wall-clock policy — per-cluster outboxes with snapshot-and-reserve
// admission, merged in cluster-index order, reproduce the serial
// injection sequence exactly, so reports are bit-identical for any
// worker count.
// ----------------------------------------------------------------------

/// Threads-1 vs threads-N on the scheme grid: every counter, decision
/// probability bit, and metric feature bit must survive the fan-out.
#[test]
fn tick_jobs_bit_identical_across_schemes() {
    let (_cfg, jobs) = grid();
    for job in &jobs {
        let label = format!("tick-jobs {} under {}", job.profile.name, job.scheme);
        let serial =
            run_benchmark_seeded_jobs(&job.cfg, &job.profile, job.scheme, job.seed, false, 1)
                .unwrap();
        for threads in [2usize, 4] {
            let fanned = run_benchmark_seeded_jobs(
                &job.cfg, &job.profile, job.scheme, job.seed, false, threads,
            )
            .unwrap();
            assert_reports_identical(&serial, &fanned, &format!("{label} x{threads}"));
        }
    }
}

/// The same contract with DynSplit transitions live: split/rebalance/
/// re-fuse timers use absolute `now` arithmetic that must not notice the
/// thread fan-out (the horizon probe runs inside the workers).
#[test]
fn tick_jobs_bit_identical_with_active_dynamic_splits() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    cfg.split_threshold = 0.05;
    cfg.split_check_period = 128;
    cfg.rebalance_period = 256;
    let mut p = bench("RAY").unwrap();
    p.num_ctas = 10;
    p.insns_per_thread = 100;
    p.num_kernels = 2;
    for scheme in [Scheme::DirectSplit, Scheme::WarpRegroup, Scheme::Hetero] {
        let label = format!("tick-jobs split-active RAY under {scheme}");
        let serial = run_benchmark_seeded_jobs(&cfg, &p, scheme, 0xA7, false, 1).unwrap();
        for threads in [2usize, 4] {
            let fanned = run_benchmark_seeded_jobs(&cfg, &p, scheme, 0xA7, false, threads).unwrap();
            assert_reports_identical(&serial, &fanned, &format!("{label} x{threads}"));
        }
    }
}

/// Multi-tenant streams with a CTA-boundary preemption in flight: the
/// server loop shares `tick_active`, so the victim requeue, the frozen
/// cluster, and every launch record must be thread-count invariant.
#[test]
fn tick_jobs_bit_identical_streams_with_preemption() {
    let (cfg, streams) = preemption_grid();
    let serial = serve_streams_jobs(&cfg, &streams, PartitionPolicy::Adaptive, false, 1).unwrap();
    assert!(serial.chip.preemptions >= 1, "the mix must actually preempt, or this pins nothing");
    for threads in [2usize, 4] {
        let fanned =
            serve_streams_jobs(&cfg, &streams, PartitionPolicy::Adaptive, false, threads).unwrap();
        assert_stream_reports_identical(
            &serial,
            &fanned,
            &format!("tick-jobs preemption streams x{threads}"),
        );
    }
    // The mixed Hetero/DynSplit-active trace under both policies too.
    let (cfg, streams) = stream_grid();
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let serial = serve_streams_jobs(&cfg, &streams, policy, false, 1).unwrap();
        for threads in [2usize, 4] {
            let fanned = serve_streams_jobs(&cfg, &streams, policy, false, threads).unwrap();
            assert_stream_reports_identical(
                &serial,
                &fanned,
                &format!("tick-jobs streams under {policy} x{threads}"),
            );
        }
    }
}

/// Faulted runs: retirement, half-SM death, MC stalls and NoC degrade
/// all mutate shared state at cycle boundaries — none of it may observe
/// the worker count.
#[test]
fn tick_jobs_bit_identical_faulted() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let trace = mixed_fault_trace();
    for name in ["BFS", "RAY"] {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in [Scheme::Baseline, Scheme::Hetero] {
            let label = format!("tick-jobs faulted {name} under {scheme}");
            let serial =
                run_benchmark_faulted_jobs(&cfg, &p, scheme, 0xD37, false, 1, &trace).unwrap();
            assert_eq!(serial.chip.faults_injected, trace.len() as u64, "{label}: faults land");
            for threads in [2usize, 4] {
                let fanned =
                    run_benchmark_faulted_jobs(&cfg, &p, scheme, 0xD37, false, threads, &trace)
                        .unwrap();
                assert_reports_identical(&serial, &fanned, &format!("{label} x{threads}"));
            }
        }
    }
}

/// The dense reference loop ignores the worker count entirely (it is the
/// auditing baseline and always ticks serially), and the fanned
/// active-set run equals that dense reference — closing the triangle
/// dense == skip == fanned-skip.
#[test]
fn tick_jobs_ignored_by_dense_and_matches_dense() {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let mut p = bench("BFS").unwrap();
    p.num_ctas = 8;
    p.insns_per_thread = 80;
    p.num_kernels = 1;
    let dense1 = run_benchmark_seeded_jobs(&cfg, &p, Scheme::Hetero, 0xD37, true, 1).unwrap();
    let dense4 = run_benchmark_seeded_jobs(&cfg, &p, Scheme::Hetero, 0xD37, true, 4).unwrap();
    assert_reports_identical(&dense1, &dense4, "dense loop must ignore tick-jobs");
    let fanned = run_benchmark_seeded_jobs(&cfg, &p, Scheme::Hetero, 0xD37, false, 4).unwrap();
    assert_reports_identical(&dense1, &fanned, "fanned active-set vs dense reference");
}

// ----------------------------------------------------------------------
// Adaptive tick-job sizing (`AMOEBA_TICK_JOBS=auto` / set_tick_jobs_auto):
// the sizer re-picks the worker count from the live-cluster census every
// cycle, so the worker count *changes across the run* — the bit-identity
// contract must hold for every census-driven count it can produce, not
// just a fixed N.
// ----------------------------------------------------------------------

/// Auto-sized fan-out vs the 1-worker walk on a chip wide enough that
/// the sizer genuinely picks multiple workers (20 clusters, hot
/// occupancy), and on a narrow chip where it stays serial — both must
/// be bit-identical to the fixed 1-worker reference.
#[test]
fn tick_jobs_auto_bit_identical_single_app() {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 40; // 20 clusters: above the 8-clusters-per-job floor
    cfg.num_mcs = 8;
    cfg.max_cycles = 1_500_000;
    let mut p = bench("BFS").unwrap();
    p.num_ctas = 80; // ~4 CTAs per cluster: the census stays high
    p.insns_per_thread = 60;
    p.num_kernels = 1;
    for scheme in [Scheme::Baseline, Scheme::Hetero] {
        let label = format!("auto tick-jobs BFS under {scheme}");
        let serial = run_benchmark_seeded_jobs(&cfg, &p, scheme, 0xD37, false, 1).unwrap();
        let auto = run_benchmark_seeded_auto(&cfg, &p, scheme, 0xD37, false).unwrap();
        assert_reports_identical(&serial, &auto, &label);
    }
    // Narrow chip: the sizer never crosses its floor, stays serial.
    let narrow = SystemConfig::tiny();
    let mut np = bench("CP").unwrap();
    np.num_ctas = 8;
    np.insns_per_thread = 80;
    np.num_kernels = 1;
    let serial = run_benchmark_seeded_jobs(&narrow, &np, Scheme::Baseline, 0xD37, false, 1).unwrap();
    let auto = run_benchmark_seeded_auto(&narrow, &np, Scheme::Baseline, 0xD37, false).unwrap();
    assert_reports_identical(&serial, &auto, "auto tick-jobs on a 2-cluster chip");
}

/// The dense reference loop ignores the auto sizer exactly as it ignores
/// a fixed worker count — and the auto-fanned active-set run still equals
/// that dense reference (dense == skip == auto-fanned-skip).
#[test]
fn tick_jobs_auto_ignored_by_dense() {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 40;
    cfg.num_mcs = 8;
    cfg.max_cycles = 1_500_000;
    let mut p = bench("BFS").unwrap();
    p.num_ctas = 80;
    p.insns_per_thread = 60;
    p.num_kernels = 1;
    let dense1 = run_benchmark_seeded_jobs(&cfg, &p, Scheme::Baseline, 0xD37, true, 1).unwrap();
    let dense_auto = run_benchmark_seeded_auto(&cfg, &p, Scheme::Baseline, 0xD37, true).unwrap();
    assert_reports_identical(&dense1, &dense_auto, "dense loop must ignore the auto sizer");
    let auto = run_benchmark_seeded_auto(&cfg, &p, Scheme::Baseline, 0xD37, false).unwrap();
    assert_reports_identical(&dense1, &auto, "auto-fanned active-set vs dense reference");
}

/// Multi-tenant streams under the auto sizer: the census swings as
/// tenants arrive and drain (exactly the regime a fixed worker count
/// can't follow), and every launch record must stay identical to the
/// 1-worker walk under both partition policies.
#[test]
fn tick_jobs_auto_bit_identical_streams() {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 24; // 12 clusters: wide enough to engage the fan-out path
    cfg.num_mcs = 8;
    cfg.max_cycles = 1_500_000;
    let tenants = [
        (bench("BFS").unwrap(), Scheme::Baseline),
        (bench("CP").unwrap(), Scheme::Baseline),
        (bench("RAY").unwrap(), Scheme::WarpRegroup),
    ];
    let mut streams = traffic_trace(&tenants, 2, 5_000, 0xD37);
    shrink_streams(&mut streams, 8, 80);
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let label = format!("auto tick-jobs streams under {policy}");
        let serial = serve_streams_jobs(&cfg, &streams, policy, false, 1).unwrap();
        let auto = serve_streams_auto(&cfg, &streams, policy, false).unwrap();
        assert_stream_reports_identical(&serial, &auto, &label);
    }
}

// ----------------------------------------------------------------------
// Fleet serving: the pool scheduler fans per-chip shards through the
// sweep executor, so the chip-thread count must be invisible in the
// FleetReport — for healthy pools AND through the health/migration
// machinery a chip loss engages.
// ----------------------------------------------------------------------

fn fleet_chip() -> SystemConfig {
    let mut c = SystemConfig::tiny();
    c.max_cycles = 300_000;
    c
}

fn fleet_trace(n: usize, seed: u64) -> Vec<KernelStream> {
    let names = ["CP", "BFS"];
    let tenants: Vec<_> =
        (0..n).map(|i| (bench(names[i % names.len()]).unwrap(), Scheme::Baseline)).collect();
    let mut streams = traffic_trace(&tenants, 2, 5_000, seed);
    shrink_streams(&mut streams, 4, 40);
    streams
}

/// Kills both clusters of a tiny chip at cycle 10 — total chip loss.
fn chip_loss() -> FaultTrace {
    FaultTrace::new(vec![
        FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
        FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
    ])
}

/// Serial vs parallel chip serving with a chip loss in flight: the
/// FleetReport — placements, health ledger, migrations, every per-chip
/// StreamReport — must be bit-identical for any executor thread count,
/// and re-serving the same fleet on a warm executor must be pure cache
/// hits (migration replay happens outside the memo and is deterministic).
#[test]
fn fleet_serial_vs_parallel_chips_bit_identical() {
    let fc = FleetConfig::pool(fleet_chip(), 3);
    let streams = fleet_trace(4, 0xD37);
    let faults = vec![chip_loss(), FaultTrace::default(), FaultTrace::default()];
    let ser = SweepExec::new(1);
    let par = SweepExec::new(4);
    let a = serve_fleet(&ser, &fc, &streams, &faults).unwrap();
    let b = serve_fleet(&par, &fc, &streams, &faults).unwrap();
    assert!(
        a.migrations >= 1 || a.dropped >= 1,
        "the chip loss must actually strand work, or this pins nothing"
    );
    assert_eq!(a, b, "fleet report must be bit-identical across chip-thread counts");
    let (_, misses_before) = par.cache_stats();
    let again = serve_fleet(&par, &fc, &streams, &faults).unwrap();
    let (_, misses_after) = par.cache_stats();
    assert_eq!(misses_before, misses_after, "re-serving the fleet must not simulate");
    assert_eq!(a, again, "re-served fleet report must be identical");
}

/// Chip-loss accounting is honest end to end: the dead chip is marked
/// Dead and quarantined, every stranded tenant either lands on a healthy
/// peer (migrated, zero drops) or is dropped with `finish == u64::MAX`
/// semantics rolled up into the drop counters — and the fleet-level
/// conservation equation holds exactly.
#[test]
fn fleet_chip_loss_accounting_is_honest() {
    let fc = FleetConfig::pool(fleet_chip(), 2);
    let streams = fleet_trace(2, 0xD37);
    let faults = vec![chip_loss(), FaultTrace::default()];
    let exec = SweepExec::new(4);
    let rep = serve_fleet(&exec, &fc, &streams, &faults).unwrap();
    let total: u32 = streams.iter().map(|s| s.launches.len() as u32).sum();
    assert_eq!(
        rep.served + rep.dropped + rep.rejected_launches,
        total,
        "every launch is served once, or honestly rejected/dropped"
    );
    assert_eq!(rep.chips[0].health, ChipHealth::Dead, "chip 0 lost every cluster");
    assert!(rep.chips[0].quarantined, "a dead chip is quarantined");
    for ft in &rep.tenants {
        if ft.rejected.is_some() {
            assert_eq!(ft.served + ft.dropped, 0, "rejected tenants never run");
            continue;
        }
        let launches = streams[ft.tenant].launches.len() as u32;
        assert_eq!(
            ft.served + ft.dropped,
            launches,
            "tenant {}: per-tenant conservation",
            ft.tenant
        );
        if ft.chip == Some(0) {
            assert!(
                ft.migrated_to.is_some() || ft.dropped > 0,
                "tenant {} was stranded on the dead chip: it must migrate or drop honestly",
                ft.tenant
            );
        }
    }
}
