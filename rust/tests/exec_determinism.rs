//! The parallel sweep executor must be a pure accelerator: its output
//! has to be bit-identical to running the same simulations serially on
//! one thread. This is the contract that lets the figure harness fan the
//! paper's sweeps across cores without changing a single plotted value.

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::harness::{SimJob, SweepExec};
use amoeba_gpu::sim::gpu::run_benchmark_seeded;
use amoeba_gpu::workload::bench;

fn grid() -> (SystemConfig, Vec<SimJob>) {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    let benches = ["CP", "BFS", "RAY"];
    let schemes = [Scheme::Baseline, Scheme::WarpRegroup, Scheme::Hetero];
    let mut jobs = Vec::new();
    for name in benches {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for s in schemes {
            jobs.push(SimJob::new(cfg.clone(), p.clone(), s, 0xD37));
        }
    }
    (cfg, jobs)
}

/// >= 3 benches x 2 schemes: every counter the figures plot must match
/// the serial path exactly, including the predictor decisions.
#[test]
fn parallel_executor_matches_serial_bit_for_bit() {
    let (_cfg, jobs) = grid();
    let exec = SweepExec::new(4);
    let parallel = exec.run_batch(jobs.clone());
    assert_eq!(parallel.len(), jobs.len());

    for (job, pr) in jobs.iter().zip(&parallel) {
        let sr = run_benchmark_seeded(&job.cfg, &job.profile, job.scheme, job.seed);
        let label = format!("{} under {}", job.profile.name, job.scheme);
        assert_eq!(sr.cycles, pr.cycles, "{label}: cycles");
        assert_eq!(sr.sm.thread_insns, pr.sm.thread_insns, "{label}: thread insns");
        assert_eq!(sr.sm.warp_insns, pr.sm.warp_insns, "{label}: warp insns");
        assert_eq!(sr.sm.l1d_accesses, pr.sm.l1d_accesses, "{label}: l1d accesses");
        assert_eq!(sr.sm.l1d_misses, pr.sm.l1d_misses, "{label}: l1d misses");
        assert_eq!(sr.sm.noc_flits, pr.sm.noc_flits, "{label}: noc flits");
        assert_eq!(sr.sm.mshr_merges, pr.sm.mshr_merges, "{label}: mshr merges");
        assert_eq!(sr.chip.dram_reads, pr.chip.dram_reads, "{label}: dram reads");
        assert_eq!(sr.chip.l2_misses, pr.chip.l2_misses, "{label}: l2 misses");
        assert_eq!(
            sr.ipc().to_bits(),
            pr.ipc().to_bits(),
            "{label}: IPC must be bit-identical"
        );
        // Predictor decisions (probability compared at the bit level).
        assert_eq!(sr.decisions.len(), pr.decisions.len(), "{label}: decision count");
        for (a, b) in sr.decisions.iter().zip(&pr.decisions) {
            assert_eq!(a.scale_up, b.scale_up, "{label}: decision");
            assert_eq!(a.cluster, b.cluster, "{label}: decision cluster");
            assert_eq!(
                a.probability.to_bits(),
                b.probability.to_bits(),
                "{label}: decision probability"
            );
        }
        // The heterogeneous scheme decides per cluster per kernel; the
        // per-cluster log must survive the parallel path intact.
        if job.scheme == Scheme::Hetero {
            let n_clusters = job.cfg.num_sms / 2;
            assert_eq!(
                pr.decisions.len(),
                n_clusters * job.profile.num_kernels as usize,
                "{label}: one decision per cluster per kernel"
            );
            for (i, d) in pr.decisions.iter().enumerate() {
                assert_eq!(d.cluster, Some((i % n_clusters) as u32), "{label}: cluster ids");
            }
        }
    }
}

/// Running the same batch twice must be pure cache hits, and a serial
/// (1-thread) executor must agree with a parallel one.
#[test]
fn serial_and_parallel_executors_agree() {
    let (_cfg, jobs) = grid();
    let par = SweepExec::new(4);
    let ser = SweepExec::serial();
    let a = par.run_batch(jobs.clone());
    let b = ser.run_batch(jobs.clone());
    for ((x, y), job) in a.iter().zip(&b).zip(&jobs) {
        assert_eq!(x.cycles, y.cycles, "{} under {}", job.profile.name, job.scheme);
        assert_eq!(x.sm.thread_insns, y.sm.thread_insns);
        assert_eq!(x.ipc().to_bits(), y.ipc().to_bits());
    }

    let (_, misses_before) = par.cache_stats();
    let again = par.run_batch(jobs.clone());
    let (_, misses_after) = par.cache_stats();
    assert_eq!(misses_before, misses_after, "re-running the batch must not simulate");
    for (x, y) in a.iter().zip(&again) {
        assert!(std::sync::Arc::ptr_eq(x, y), "cached Arc must be returned");
    }
}
