//! End-to-end integration tests: whole-machine simulations across schemes,
//! conservation invariants, and reproduction-shape checks on shrunken
//! workloads (the full-size shapes are validated by `figures` runs and
//! recorded in EXPERIMENTS.md).

use amoeba_gpu::config::{NocMode, Scheme, SystemConfig};
use amoeba_gpu::sim::core::ClusterMode;
use amoeba_gpu::sim::gpu::{run_benchmark_seeded, SimReport};
use amoeba_gpu::workload::{all_benchmarks, bench, BenchProfile};

fn small_cfg() -> SystemConfig {
    let mut c = SystemConfig::gtx480();
    c.num_sms = 8;
    c.num_mcs = 4;
    c.max_cycles = 3_000_000;
    c.profile_window = 1_000;
    c
}

fn shrink(mut p: BenchProfile) -> BenchProfile {
    p.num_ctas = 24;
    p.insns_per_thread = 120;
    p.num_kernels = 1;
    p
}

/// Every benchmark completes under every scheme and conserves work:
/// thread-instructions executed >= grid size x trace length.
#[test]
fn every_benchmark_completes_under_every_scheme() {
    let cfg = small_cfg();
    for p in all_benchmarks() {
        let p = shrink(p);
        let expect_insns = p.num_ctas as u64 * p.cta_threads as u64 * p.insns_per_thread as u64;
        for scheme in Scheme::ALL {
            let r = run_benchmark_seeded(&cfg, &p, scheme, 42).unwrap();
            assert_eq!(
                r.chip.kernels_completed, 1,
                "{} under {scheme} did not finish",
                p.name
            );
            assert!(
                r.sm.thread_insns >= expect_insns,
                "{} under {scheme}: executed {} < expected {expect_insns}",
                p.name,
                r.sm.thread_insns
            );
            assert!(r.ipc() > 0.05, "{} under {scheme}: ipc {}", p.name, r.ipc());
        }
    }
}

/// The SM benchmark (the paper's headline) must show a strong scale-up
/// win; CP must not.
#[test]
fn headline_capacity_effect() {
    let cfg = SystemConfig::gtx480();
    let mut p = bench("SM").unwrap();
    p.num_ctas = 48;
    p.num_kernels = 1;
    let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 7).unwrap();
    let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, 7).unwrap();
    assert!(
        fused.ipc() > base.ipc() * 1.5,
        "SM fused speedup too small: {:.2}",
        fused.ipc() / base.ipc()
    );
    // The L1D miss-rate drop is the mechanism (Fig 15).
    assert!(
        fused.sm.l1d_miss_rate() < base.sm.l1d_miss_rate() * 0.7,
        "L1D miss {:.3} -> {:.3}",
        base.sm.l1d_miss_rate(),
        fused.sm.l1d_miss_rate()
    );

    let mut cp = bench("CP").unwrap();
    cp.num_ctas = 48;
    cp.num_kernels = 1;
    let cb = run_benchmark_seeded(&cfg, &cp, Scheme::Baseline, 7).unwrap();
    let cf = run_benchmark_seeded(&cfg, &cp, Scheme::ScaleUp, 7).unwrap();
    assert!(
        cf.ipc() < cb.ipc() * 1.05,
        "CP should not benefit from fusion: {:.2}",
        cf.ipc() / cb.ipc()
    );
}

/// The predictor-driven scheme must track the better static choice within
/// a tolerance (it pays profiling + reconfiguration overhead).
#[test]
fn static_fuse_tracks_oracle() {
    let cfg = small_cfg();
    for name in ["SM", "CP"] {
        let p = shrink(bench(name).unwrap());
        let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 3).unwrap().ipc();
        let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, 3).unwrap().ipc();
        let amoeba = run_benchmark_seeded(&cfg, &p, Scheme::StaticFuse, 3).unwrap().ipc();
        let oracle = base.max(fused);
        // On this deliberately tiny kernel (24 CTAs) the profiling probe
        // wave + drain + reconfiguration cost is a large fraction of the
        // whole run, so the tracking bound is loose; full-size kernels
        // amortise it (see EXPERIMENTS.md Fig 12).
        assert!(
            amoeba > oracle * 0.6,
            "{name}: static fuse {amoeba:.1} vs oracle {oracle:.1}"
        );
    }
}

/// Perfect-NoC mode must never be slower than the mesh (Fig 3b premise).
#[test]
fn perfect_noc_dominates_mesh() {
    let mut cfg = small_cfg();
    for name in ["MUM", "LPS"] {
        let p = shrink(bench(name).unwrap());
        cfg.noc_mode = NocMode::Mesh;
        let mesh = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 5).unwrap();
        cfg.noc_mode = NocMode::Perfect;
        let perfect = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 5).unwrap();
        assert!(
            perfect.ipc() >= mesh.ipc() * 0.98,
            "{name}: perfect {:.1} < mesh {:.1}",
            perfect.ipc(),
            mesh.ipc()
        );
    }
}

/// Dynamic splitting must engage on divergent fused workloads and produce
/// both split and re-fuse events (Fig 19's dynamics).
#[test]
fn dynamic_split_engages_on_divergent_workloads() {
    let cfg = small_cfg();
    let p = shrink(bench("RAY").unwrap());
    let r = run_benchmark_seeded(&cfg, &p, Scheme::WarpRegroup, 11).unwrap();
    if r.decisions.first().map(|d| d.scale_up).unwrap_or(false) {
        assert!(r.sm.split_events > 0, "no splits on RAY despite fusing");
        assert!(r.sm.split_cycles > 0);
    }
    // Phase trace records mode changes.
    assert!(!r.phases.is_empty());
}

/// The heterogeneous scheme (§4.4) must record one decision and one
/// metric sample per cluster per kernel, with stable cluster ids.
#[test]
fn hetero_decides_every_cluster_independently() {
    let cfg = small_cfg(); // 8 SMs => 4 clusters
    let n_clusters = cfg.num_sms / 2;
    let p = shrink(bench("SM").unwrap());
    let r = run_benchmark_seeded(&cfg, &p, Scheme::Hetero, 5).unwrap();
    assert_eq!(r.chip.kernels_completed, 1);
    assert_eq!(r.decisions.len(), n_clusters, "one decision per cluster per kernel");
    assert_eq!(r.samples.len(), n_clusters);
    for k in 0..p.num_kernels as usize {
        for ci in 0..n_clusters {
            assert_eq!(r.decisions[k * n_clusters + ci].cluster, Some(ci as u32));
        }
    }
    assert!(r.ipc() > 0.05, "ipc={}", r.ipc());
}

/// A divergence-heavy, memory-heavy two-kernel app near the predictor's
/// decision boundary must produce at least one *mixed* phase sample —
/// some clusters fused (or split), some private, in the same cycle. The
/// memory intensity is swept across the boundary and a few seeds each,
/// because which side of 0.5 each cluster's probe CTA lands on is a
/// property of its own measured window (that independence is the point).
#[test]
fn hetero_mixes_cluster_modes_on_boundary_workloads() {
    let cfg = SystemConfig::tiny(); // 4 SMs => 2 clusters
    let mut tried = 0u32;
    for ld_step in 0..=10 {
        let frac_ld = 0.10 + ld_step as f64 * 0.02;
        for seed in 0..10u64 {
            // Divergence-heavy (RAY's branch profile) + tunable memory
            // intensity, two kernels so the decision re-runs per kernel.
            let mut p = bench("RAY").unwrap();
            p.num_ctas = 12;
            p.insns_per_thread = 150;
            p.num_kernels = 2;
            p.frac_ld = frac_ld;
            p.validate().unwrap();
            let r = run_benchmark_seeded(&cfg, &p, Scheme::Hetero, seed).unwrap();
            tried += 1;
            assert_eq!(r.chip.kernels_completed, 2, "frac_ld={frac_ld} seed={seed}");
            assert_eq!(r.decisions.len(), 2 * 2, "one decision per cluster per kernel");
            let mixed = r.phases.iter().any(|ph| {
                let non_private = ph
                    .modes
                    .iter()
                    .filter(|m| !matches!(m, ClusterMode::PrivatePair))
                    .count();
                non_private > 0 && non_private < ph.modes.len()
            });
            if mixed {
                return; // found a heterogeneous population
            }
        }
    }
    panic!("no mixed-mode phase across {tried} boundary runs");
}

/// Determinism: identical seeds give identical cycle counts and stats.
#[test]
fn fully_deterministic() {
    let cfg = small_cfg();
    let p = shrink(bench("BFS").unwrap());
    let reports: Vec<SimReport> = (0..2)
        .map(|_| run_benchmark_seeded(&cfg, &p, Scheme::WarpRegroup, 99).unwrap())
        .collect();
    assert_eq!(reports[0].cycles, reports[1].cycles);
    assert_eq!(reports[0].sm.thread_insns, reports[1].sm.thread_insns);
    assert_eq!(reports[0].sm.l1d_misses, reports[1].sm.l1d_misses);
    assert_eq!(reports[0].sm.noc_flits, reports[1].sm.noc_flits);
    assert_eq!(reports[0].chip.dram_reads, reports[1].chip.dram_reads);
}

/// MC-injection stalls must react to memory pressure (Fig 17's metric is
/// live) and be reduced by fusing on reply-bound workloads.
#[test]
fn icnt_stall_metric_is_live() {
    let cfg = small_cfg();
    let p = shrink(bench("CORR").unwrap());
    let base = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, 2).unwrap();
    let fused = run_benchmark_seeded(&cfg, &p, Scheme::ScaleUp, 2).unwrap();
    // CORR is reply-heavy: baseline must observe some stall pressure.
    assert!(base.chip.mc_cycles > 0);
    assert!(
        fused.chip.mc_inject_stall_rate() <= base.chip.mc_inject_stall_rate() * 1.1 + 1e-9,
        "fusing should not worsen ICNT stalls: {:.4} -> {:.4}",
        base.chip.mc_inject_stall_rate(),
        fused.chip.mc_inject_stall_rate()
    );
}
