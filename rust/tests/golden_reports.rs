//! Golden-report regression suite: committed fingerprints of simulator
//! output that future refactors must reproduce **exactly**.
//!
//! `exec_determinism.rs` proves the simulator agrees with *itself*
//! (skip == dense, parallel == serial) — it cannot catch a refactor that
//! shifts results in both modes at once. This suite pins absolute
//! behaviour: a small matrix of benchmarks x all schemes (plus two
//! multi-tenant stream runs) is simulated with fixed seeds and compared
//! against goldens committed under `tests/goldens/`.
//!
//! Fingerprint format: a small JSON document with human-readable
//! headline fields (cycles, stall breakdown, cache counters, decisions)
//! for diff-localisation, plus `report_fnv` — an FNV-1a hash over the
//! full `Debug` rendering of the report, so **every** field participates
//! automatically (a newly added counter can never silently escape the
//! golden, the same property the sweep-cache fingerprints rely on).
//!
//! Blessing:
//! * `AMOEBA_BLESS=1 cargo test --test golden_reports` rewrites every
//!   golden from the current behaviour (then commit the diff).
//! * A *missing* golden is written on first run (loudly) and the test
//!   passes — this is how the initial goldens materialise on the first
//!   toolchain-equipped host; commit them. A *present but different*
//!   golden always fails.
//!
//! The suite runs under both execution modes in CI (`ci.sh` repeats it
//! with `AMOEBA_DENSE=1`); the committed goldens are mode-independent by
//! the skip==dense contract.

use std::path::PathBuf;

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::sim::gpu::{
    run_benchmark_seeded, serve_streams, PartitionPolicy, SimReport, StreamReport,
};
use amoeba_gpu::workload::{
    bench, shrink_streams, traffic_trace, traffic_trace_qos, Priority, TenantQosSpec,
    TrafficPattern,
};

const SEED: u64 = 0x601D;

/// FNV-1a (mirrors `harness::exec`; kept local so the test pins its own
/// definition).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("goldens")
}

fn push_kv(out: &mut String, key: &str, val: impl std::fmt::Display) {
    out.push_str(&format!("  \"{key}\": {val},\n"));
}

/// Stable fingerprint document for one `SimReport`.
fn fingerprint(r: &SimReport) -> String {
    let mut s = String::from("{\n");
    push_kv(&mut s, "bench", format!("\"{}\"", r.bench));
    push_kv(&mut s, "scheme", format!("\"{}\"", r.scheme));
    push_kv(&mut s, "cycles", r.cycles);
    push_kv(&mut s, "ipc_bits", format!("\"{:#018x}\"", r.ipc().to_bits()));
    // Stall breakdown.
    push_kv(&mut s, "stall_idle", r.sm.stall_idle);
    push_kv(&mut s, "stall_memory", r.sm.stall_memory);
    push_kv(&mut s, "stall_control", r.sm.stall_control);
    push_kv(&mut s, "stall_barrier", r.sm.stall_barrier);
    push_kv(&mut s, "stall_exec", r.sm.stall_exec);
    push_kv(&mut s, "stall_mem_struct", r.sm.stall_mem_struct);
    // Cache behaviour (counters, not rates: exact by construction).
    push_kv(&mut s, "l1d", format!("[{}, {}]", r.sm.l1d_accesses, r.sm.l1d_misses));
    push_kv(&mut s, "l1i", format!("[{}, {}]", r.sm.l1i_accesses, r.sm.l1i_misses));
    push_kv(&mut s, "l1c", format!("[{}, {}]", r.sm.l1c_accesses, r.sm.l1c_misses));
    push_kv(&mut s, "l2", format!("[{}, {}]", r.chip.l2_accesses, r.chip.l2_misses));
    push_kv(&mut s, "mshr", format!("[{}, {}]", r.sm.mshr_allocs, r.sm.mshr_merges));
    push_kv(&mut s, "dram_rw", format!("[{}, {}]", r.chip.dram_reads, r.chip.dram_writes));
    push_kv(&mut s, "insns", format!("[{}, {}]", r.sm.warp_insns, r.sm.thread_insns));
    push_kv(&mut s, "retired", format!("[{}, {}]", r.sm.ctas_retired, r.sm.warps_retired));
    push_kv(
        &mut s,
        "mode_cycles",
        format!("[{}, {}]", r.sm.fused_cycles, r.sm.split_cycles),
    );
    push_kv(
        &mut s,
        "events",
        format!(
            "[{}, {}, {}]",
            r.sm.fuse_events, r.sm.split_events, r.chip.reconfig_events
        ),
    );
    // Controller decisions, probability pinned at the bit level.
    let decisions: Vec<String> = r
        .decisions
        .iter()
        .map(|d| {
            format!(
                "{{\"cluster\": {}, \"scale_up\": {}, \"p_bits\": \"{:#018x}\"}}",
                d.cluster.map(|c| c as i64).unwrap_or(-1),
                d.scale_up,
                d.probability.to_bits()
            )
        })
        .collect();
    s.push_str(&format!("  \"decisions\": [{}],\n", decisions.join(", ")));
    push_kv(&mut s, "phases", r.phases.len());
    push_kv(&mut s, "samples", r.samples.len());
    // Field-complete hash: the Debug rendering covers every counter,
    // decision, phase sample, and metric sample.
    s.push_str(&format!("  \"report_fnv\": \"{:#018x}\"\n}}\n", fnv1a(&format!("{r:?}"))));
    s
}

/// Stable fingerprint document for one multi-tenant `StreamReport`.
fn fingerprint_stream(r: &StreamReport) -> String {
    let mut s = String::from("{\n");
    push_kv(&mut s, "cycles", r.cycles);
    push_kv(&mut s, "kernels", r.chip.kernels_completed);
    push_kv(&mut s, "reconfigs", r.chip.reconfig_events);
    push_kv(&mut s, "l2", format!("[{}, {}]", r.chip.l2_accesses, r.chip.l2_misses));
    push_kv(&mut s, "chip_ctas", r.sm.ctas_retired);
    let tenants: Vec<String> = r
        .tenants
        .iter()
        .map(|t| {
            format!(
                "{{\"name\": \"{}\", \"finish\": {}, \"insns\": {}, \"ctas\": {}, \"decisions\": {}}}",
                t.bench, t.cycles, t.sm.thread_insns, t.sm.ctas_retired, t.decisions.len()
            )
        })
        .collect();
    s.push_str(&format!("  \"tenants\": [{}],\n", tenants.join(", ")));
    push_kv(&mut s, "preemptions", r.chip.preemptions);
    push_kv(&mut s, "ctas_preempted", r.chip.ctas_preempted);
    let launches: Vec<String> = r
        .launches
        .iter()
        .map(|l| {
            format!("[{}, {}, {}, {}, {}]", l.tenant, l.kernel, l.start, l.finish, l.queue_delay)
        })
        .collect();
    s.push_str(&format!("  \"launches\": [{}],\n", launches.join(", ")));
    s.push_str(&format!("  \"report_fnv\": \"{:#018x}\"\n}}\n", fnv1a(&format!("{r:?}"))));
    s
}

/// Compare `actual` against the committed golden `name.json`, blessing
/// when asked (`AMOEBA_BLESS=1`) or when the golden does not exist yet.
fn check_golden(name: &str, actual: &str) {
    let dir = goldens_dir();
    let path = dir.join(format!("{name}.json"));
    let bless = std::env::var("AMOEBA_BLESS").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::create_dir_all(&dir).expect("create goldens dir");
        std::fs::write(&path, actual).expect("write golden");
        eprintln!(
            "[golden] {} {} — commit it",
            if bless { "re-blessed" } else { "created missing golden" },
            path.display()
        );
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden");
    if expected != actual {
        let diff: String = expected
            .lines()
            .zip(actual.lines())
            .filter(|(e, a)| e != a)
            .map(|(e, a)| format!("  - {e}\n  + {a}\n"))
            .collect();
        panic!(
            "golden mismatch for {name} (first differing lines below).\n\
             If the change is intentional, re-bless with AMOEBA_BLESS=1 and commit.\n{diff}"
        );
    }
}

fn quick_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::tiny();
    cfg.max_cycles = 1_500_000;
    cfg
}

/// >= 3 profiles x all 7 schemes (incl. Hetero), fixed seed, quick
/// configs — the absolute-behaviour pin for the single-application path.
#[test]
fn golden_single_application_matrix() {
    let cfg = quick_cfg();
    for name in ["CP", "BFS", "RAY"] {
        let mut p = bench(name).unwrap();
        p.num_ctas = 8;
        p.insns_per_thread = 80;
        p.num_kernels = 1;
        for scheme in Scheme::ALL {
            let r = run_benchmark_seeded(&cfg, &p, scheme, SEED).unwrap();
            assert_eq!(r.chip.kernels_completed, 1, "{name} under {scheme} must complete");
            check_golden(&format!("{}_{}", name.to_lowercase(), scheme), &fingerprint(&r));
        }
    }
}

/// Multi-tenant stream runs under both partition policies.
#[test]
fn golden_stream_runs() {
    // tiny() has 2 clusters; widen to 8 SMs so three tenants fit with a
    // cluster to spare.
    let mut cfg = quick_cfg();
    cfg.num_sms = 8;
    cfg.num_mcs = 4;
    let tenants = vec![
        (bench("BFS").unwrap(), Scheme::Hetero),
        (bench("RAY").unwrap(), Scheme::WarpRegroup),
        (bench("CP").unwrap(), Scheme::Baseline),
    ];
    let mut streams = traffic_trace(&tenants, 2, 10_000, SEED);
    shrink_streams(&mut streams, 6, 60);
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let r = serve_streams(&cfg, &streams, policy).unwrap();
        assert!(
            r.launches.iter().all(|l| l.finish != u64::MAX),
            "{policy}: all launches must be served"
        );
        check_golden(&format!("stream_{policy}"), &fingerprint_stream(&r));
    }
}

/// The default priority mix (High with an SLO, Normal, Low) on a bursty
/// trace under the Adaptive policy — the partition-scoped-drain +
/// preemption path pinned absolutely. Same bless-on-missing workflow as
/// the other goldens.
#[test]
fn golden_priority_mix() {
    let mut cfg = quick_cfg();
    cfg.num_sms = 8;
    cfg.num_mcs = 4;
    let prios = [Priority::High, Priority::Normal, Priority::Low];
    let specs: Vec<TenantQosSpec> = vec![
        (bench("BFS").unwrap(), Scheme::Hetero),
        (bench("RAY").unwrap(), Scheme::WarpRegroup),
        (bench("CP").unwrap(), Scheme::Baseline),
    ]
    .into_iter()
    .zip(prios)
    .map(|((profile, scheme), priority)| TenantQosSpec {
        profile,
        scheme,
        priority,
        slo_turnaround: (priority == Priority::High).then_some(400_000),
    })
    .collect();
    let mut streams = traffic_trace_qos(
        &specs,
        2,
        10_000,
        SEED,
        TrafficPattern::Bursty { burst_len: 4, dilation: 8 },
    );
    shrink_streams(&mut streams, 6, 60);
    let r = serve_streams(&cfg, &streams, PartitionPolicy::Adaptive).unwrap();
    assert!(r.launches.iter().all(|l| l.finish != u64::MAX), "all launches must be served");
    check_golden("stream_priority_mix", &fingerprint_stream(&r));
}

/// Mid-trace checkpoint migration under a bursty mixed-priority QoS
/// trace: the whole chip dies at cycle 8k, the retry budget is zero,
/// and every stranded tenant must be rescued by the checkpoint path —
/// captured just before the first fault, pending faults stripped,
/// finished on a restored healthy machine. Pins the shared (faulted)
/// run and the per-tenant health ledger absolutely.
#[test]
fn golden_stream_migration() {
    use amoeba_gpu::runtime::serve::{serve_with_failover, FailoverConfig};
    use amoeba_gpu::sim::fault::{FaultEvent, FaultKind, FaultTrace};

    let mut cfg = quick_cfg();
    cfg.num_sms = 8;
    cfg.num_mcs = 4;
    cfg.max_cycles = 400_000;
    let prios = [Priority::High, Priority::Normal, Priority::Low];
    let specs: Vec<TenantQosSpec> = vec![
        (bench("BFS").unwrap(), Scheme::Hetero),
        (bench("RAY").unwrap(), Scheme::WarpRegroup),
        (bench("CP").unwrap(), Scheme::Baseline),
    ]
    .into_iter()
    .zip(prios)
    .map(|((profile, scheme), priority)| TenantQosSpec {
        profile,
        scheme,
        priority,
        slo_turnaround: (priority == Priority::High).then_some(400_000),
    })
    .collect();
    let mut streams = traffic_trace_qos(
        &specs,
        2,
        10_000,
        SEED,
        TrafficPattern::Bursty { burst_len: 4, dilation: 8 },
    );
    shrink_streams(&mut streams, 6, 60);
    // Kill every cluster mid-trace; with no retry budget only the
    // checkpoint migration can rescue the stranded launches.
    let faults = FaultTrace::new(
        (0..4).map(|c| FaultEvent { cycle: 8_000, kind: FaultKind::Cluster { cluster: c } }).collect(),
    );
    let fo = FailoverConfig { max_retries: 0, quarantine_after: 1, ..FailoverConfig::default() };
    let (shared, health) =
        serve_with_failover(&cfg, &streams, PartitionPolicy::Adaptive, &fo, &faults).unwrap();
    assert!(shared.deadline_hit, "dead chip must truncate the shared run");
    for (ti, h) in health.iter().enumerate() {
        assert!(h.migrated, "tenant {ti} must have been migrated");
        assert_eq!(h.dropped, 0, "migration must serve everything");
        assert_eq!(h.served as usize, streams[ti].launches.len());
    }

    let mut s = String::from("{\n");
    push_kv(&mut s, "shared_cycles", shared.cycles);
    push_kv(&mut s, "deadline_hit", shared.deadline_hit);
    push_kv(&mut s, "faults_injected", shared.chip.faults_injected);
    push_kv(&mut s, "clusters_retired", shared.chip.clusters_retired);
    let hj: Vec<String> = health
        .iter()
        .map(|h| {
            format!(
                "{{\"tenant\": {}, \"attempts\": {}, \"failures\": {}, \"quarantined\": {}, \
                 \"served\": {}, \"dropped\": {}, \"migrated\": {}}}",
                h.tenant, h.attempts, h.failures, h.quarantined, h.served, h.dropped, h.migrated
            )
        })
        .collect();
    s.push_str(&format!("  \"health\": [{}],\n", hj.join(", ")));
    s.push_str(&format!("  \"shared_fnv\": \"{:#018x}\",\n", fnv1a(&format!("{shared:?}"))));
    s.push_str(&format!("  \"health_fnv\": \"{:#018x}\"\n}}\n", fnv1a(&format!("{health:?}"))));
    check_golden("stream_migration", &s);
}

/// The fingerprint must be sensitive to single-counter perturbations —
/// the property that makes a deliberate one-line change (e.g. an extra
/// cache-clock bump) fail the suite.
#[test]
fn fingerprint_detects_single_counter_perturbations() {
    let cfg = quick_cfg();
    let mut p = bench("CP").unwrap();
    p.num_ctas = 4;
    p.insns_per_thread = 40;
    p.num_kernels = 1;
    let r = run_benchmark_seeded(&cfg, &p, Scheme::Baseline, SEED).unwrap();
    let base = fingerprint(&r);
    assert_eq!(base, fingerprint(&r), "fingerprint is a pure function");

    let mut bumped = r.clone();
    bumped.chip.l2_accesses += 1;
    assert_ne!(base, fingerprint(&bumped), "chip counter bump must change the fingerprint");

    let mut stalled = r.clone();
    stalled.sm.stall_memory += 1;
    assert_ne!(base, fingerprint(&stalled), "stall bump must change the fingerprint");

    // Even a field the headline section does not print is caught by the
    // Debug-rendering hash.
    let mut subtle = r.clone();
    subtle.sm.noc_latency_sum += 1;
    assert_ne!(base, fingerprint(&subtle), "report_fnv must cover every field");

    if let Some(d) = r.decisions.first() {
        let mut flipped = r.clone();
        flipped.decisions[0].probability = d.probability + 1e-12;
        assert_ne!(base, fingerprint(&flipped), "probability bits are pinned");
    }
}
