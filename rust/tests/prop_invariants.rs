//! Property-based tests over the coordinator's core invariants
//! (randomised with the in-repo PCG RNG; proptest is not available in the
//! offline vendored registry, so shrinking is replaced by printing the
//! failing seed — rerun with that seed to reproduce).

use amoeba_gpu::config::{Scheme, SystemConfig};
use amoeba_gpu::isa::{AccessPattern, ActiveMask};
use amoeba_gpu::sim::core::{ClusterMode, SmCluster};
use amoeba_gpu::sim::fault::{FaultEvent, FaultKind, FaultTrace};
use amoeba_gpu::sim::gpu::{
    run_benchmark_faulted, run_benchmark_seeded, run_benchmark_seeded_jobs, serve_streams,
    serve_streams_dense, serve_streams_faulted, serve_streams_jobs, PartitionPolicy,
};
use amoeba_gpu::sim::mem::{
    coalesce, coalesce_fused, Access, Cache, DramRequest, MemPartition, MemoryController,
};
use amoeba_gpu::sim::noc::{Noc, Packet, Payload, Subnet};
use amoeba_gpu::sim::NextEvent;
use amoeba_gpu::workload::{
    bench, kernel_launches, shrink_streams, traffic_trace, KernelStream, Pcg32, Priority, TraceGen,
};

/// Randomised property: coalescing never produces more transactions than
/// active lanes, never zero for a non-empty mask, and is deterministic.
#[test]
fn prop_coalesce_bounds() {
    let mut rng = Pcg32::new(0xC0A1, 1);
    for case in 0..500 {
        let width = [8usize, 16, 32][rng.next_bounded(3) as usize];
        let mask = ActiveMask(rng.next_u64() & ActiveMask::full(width).0);
        let pattern = match rng.next_bounded(3) {
            0 => AccessPattern::Strided {
                base: rng.next_u64() % (1 << 30),
                stride: [4u32, 8, 64, 256][rng.next_bounded(4) as usize],
            },
            1 => AccessPattern::Broadcast { base: rng.next_u64() % (1 << 30) },
            _ => AccessPattern::Scatter { base: 0, seed: rng.next_u64() },
        };
        let r = coalesce(&pattern, mask, width, 128);
        let active = mask.lanes().take_while(|&l| l < width).count();
        assert!(r.transactions() <= active.max(1), "case {case}: txns > lanes");
        assert_eq!(r.requests as usize, active, "case {case}");
        if active > 0 {
            assert!(r.transactions() >= 1, "case {case}");
        }
        let r2 = coalesce(&pattern, mask, width, 128);
        assert_eq!(r.lines, r2.lines, "case {case}: nondeterministic");
        // Every line is line-aligned.
        assert!(r.lines.iter().all(|l| l % 128 == 0), "case {case}");
    }
}

/// Fused coalescing never produces more transactions than running the two
/// sub-warps through separate coalescers (the paper's Fig 4 direction).
#[test]
fn prop_fused_coalescing_never_worse() {
    let mut rng = Pcg32::new(0xF00D, 2);
    for case in 0..500 {
        let mk = |rng: &mut Pcg32| match rng.next_bounded(3) {
            0 => AccessPattern::Strided {
                base: rng.next_u64() % (1 << 24),
                stride: [4u32, 16, 128][rng.next_bounded(3) as usize],
            },
            1 => AccessPattern::Broadcast { base: rng.next_u64() % (1 << 24) },
            _ => AccessPattern::Scatter { base: 0, seed: rng.next_u64() },
        };
        let (a, b) = (mk(&mut rng), mk(&mut rng));
        let fused = coalesce_fused(&a, &b, ActiveMask::full(64), 128);
        let sep =
            coalesce(&a, ActiveMask::full(32), 32, 128).transactions()
                + coalesce(&b, ActiveMask::full(32), 32, 128).transactions();
        assert!(
            fused.transactions() <= sep,
            "case {case}: fused {} > separate {sep}",
            fused.transactions()
        );
    }
}

/// Cache invariant: every MissNew is eventually balanced by exactly one
/// fill, MSHR occupancy never exceeds capacity, and a filled line hits.
#[test]
fn prop_cache_mshr_balance() {
    let mut rng = Pcg32::new(0xCACE, 3);
    for case in 0..100 {
        let mshrs = 1 + rng.next_bounded(16) as usize;
        let mut cache = Cache::new(4096, 2, 128, 1, mshrs);
        let mut outstanding: Vec<u64> = Vec::new();
        for _ in 0..400 {
            if rng.chance(0.6) || outstanding.is_empty() {
                let addr = (rng.next_u64() % (1 << 16)) & !127;
                match cache.access(addr) {
                    Access::MissNew => outstanding.push(addr),
                    Access::MshrFull => {
                        assert_eq!(
                            cache.mshrs_in_flight(),
                            mshrs,
                            "case {case}: MshrFull below capacity"
                        );
                    }
                    Access::Hit | Access::MissMerged => {}
                }
            } else {
                let i = rng.next_bounded(outstanding.len() as u32) as usize;
                let addr = outstanding.swap_remove(i);
                let released = cache.fill(addr);
                assert!(released >= 1, "case {case}: fill released nothing");
                assert_eq!(cache.access(addr), Access::Hit, "case {case}: fill not resident");
            }
            assert!(cache.mshrs_in_flight() <= mshrs, "case {case}: MSHR overflow");
        }
        // Drain.
        for addr in outstanding.drain(..) {
            cache.fill(addr);
        }
        assert_eq!(cache.mshrs_in_flight(), 0, "case {case}: leaked MSHRs");
    }
}

/// NoC conservation: every injected packet is ejected exactly once at its
/// destination, regardless of load pattern.
#[test]
fn prop_noc_conservation() {
    let mut rng = Pcg32::new(0x0C0C, 4);
    for case in 0..30 {
        let cfg = SystemConfig::tiny();
        let nodes = 4 + rng.next_bounded(12) as usize;
        let mut noc = Noc::with_nodes(&cfg, nodes);
        let mut sent = vec![0u32; nodes];
        let mut got = vec![0u32; nodes];
        let mut t = 0u64;
        let total_offers = 200 + rng.next_bounded(300);
        let mut offered = 0;
        while t < 20_000 {
            if offered < total_offers {
                let src = rng.next_bounded(nodes as u32) as usize;
                let dst = rng.next_bounded(nodes as u32) as usize;
                let pkt = Packet {
                    src,
                    dst,
                    flits: 1 + rng.next_bounded(5),
                    born: t,
                    payload: Payload::MemRequest { line: 0, requester: 0, is_write: false },
                };
                if noc.inject(Subnet::Request, pkt) {
                    sent[dst] += 1;
                    offered += 1;
                }
            }
            noc.tick(t);
            for n in 0..nodes {
                while noc.eject(Subnet::Request, n).is_some() {
                    got[n] += 1;
                }
            }
            if offered >= total_offers && !noc.busy() {
                break;
            }
            t += 1;
        }
        assert_eq!(sent, got, "case {case}: packet conservation violated");
        assert!(!noc.busy(), "case {case}: packets stranded");
    }
}

/// FR-FCFS conservation: every accepted DRAM request is answered once.
#[test]
fn prop_dram_conservation() {
    let mut rng = Pcg32::new(0xD3A3, 5);
    for case in 0..50 {
        let mut mc = MemoryController::new(
            1 + rng.next_bounded(8) as usize,
            2048,
            40,
            110,
            4 + rng.next_bounded(28) as usize,
        );
        let mut accepted = 0u32;
        let mut answered = 0u32;
        let mut tags = std::collections::HashSet::new();
        let mut t = 0u64;
        while t < 60_000 {
            if rng.chance(0.4) && accepted < 300 {
                let req = amoeba_gpu::sim::mem::DramRequest {
                    addr: (rng.next_u64() % (1 << 20)) & !127,
                    is_write: rng.chance(0.3),
                    tag: accepted as u64,
                };
                if mc.push(req) {
                    accepted += 1;
                }
            }
            mc.tick(t);
            while let Some(r) = mc.pop_reply() {
                answered += 1;
                assert!(tags.insert(r.tag), "case {case}: duplicate reply tag {}", r.tag);
            }
            if accepted >= 300 && !mc.busy() {
                break;
            }
            t += 1;
        }
        assert_eq!(accepted, answered, "case {case}: dram lost/duplicated requests");
    }
}

/// Event-horizon tightness, DRAM side: `next_event` must never promise a
/// horizon later than the first observable state change the dense tick
/// loop would make. (Earlier is allowed — the loop just skips less.)
#[test]
fn prop_mc_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x3E47, 7);
    for case in 0..40 {
        let mut mc = MemoryController::new(
            1 + rng.next_bounded(8) as usize,
            2048,
            40,
            110,
            4 + rng.next_bounded(28) as usize,
        );
        // Phase A: dense warm-up with random arrivals (promises are only
        // checked in windows with no external input, since a push can
        // legitimately create activity inside a previously-quiet window).
        let mut tag = 0u64;
        let mut t = 0u64;
        for _ in 0..150 {
            if rng.chance(0.5) {
                let _ = mc.push(DramRequest {
                    addr: (rng.next_u64() % (1 << 20)) & !127,
                    is_write: rng.chance(0.3),
                    tag: { tag += 1; tag },
                });
            }
            mc.tick(t);
            while mc.pop_reply().is_some() {}
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let snap = |m: &MemoryController| m.reads + m.writes + m.row_hits + m.row_misses;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "case {case}: no convergence");
            match mc.next_event(t) {
                NextEvent::Idle => {
                    assert!(!mc.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    mc.tick(t);
                    while mc.pop_reply().is_some() {}
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = snap(&mc);
                        mc.tick(t);
                        let mut popped = 0;
                        while mc.pop_reply().is_some() {
                            popped += 1;
                        }
                        assert!(
                            snap(&mc) == before && popped == 0,
                            "case {case}: state changed at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Event-horizon tightness, NoC side: within a promised window no packet
/// may move (no flits routed, nothing delivered or ejectable).
#[test]
fn prop_noc_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x90C7, 8);
    for case in 0..30 {
        let cfg = SystemConfig::tiny();
        let nodes = 4 + rng.next_bounded(12) as usize;
        let mut noc = Noc::with_nodes(&cfg, nodes);
        let mut t = 0u64;
        // Phase A: dense warm-up under random load.
        for _ in 0..100 {
            if rng.chance(0.6) {
                let src = rng.next_bounded(nodes as u32) as usize;
                let dst = rng.next_bounded(nodes as u32) as usize;
                let _ = noc.inject(
                    Subnet::Request,
                    Packet {
                        src,
                        dst,
                        flits: 1 + rng.next_bounded(5),
                        born: t,
                        payload: Payload::MemRequest { line: 0, requester: 0, is_write: false },
                    },
                );
            }
            noc.tick(t);
            for n in 0..nodes {
                while noc.eject(Subnet::Request, n).is_some() {}
            }
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 20_000, "case {case}: no convergence");
            match noc.next_event(t) {
                NextEvent::Idle => {
                    assert!(!noc.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    noc.tick(t);
                    for n in 0..nodes {
                        while noc.eject(Subnet::Request, n).is_some() {}
                    }
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = (noc.flits_routed, noc.packets_delivered);
                        noc.tick(t);
                        assert_eq!(
                            (noc.flits_routed, noc.packets_delivered),
                            before,
                            "case {case}: packet moved at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Event-horizon tightness, memory-partition side (L2 hit pipeline +
/// DRAM behind it): within a promised window the partition emits no
/// reply and schedules no DRAM access.
#[test]
fn prop_partition_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x9A47, 9);
    for case in 0..30 {
        let mut p = MemPartition::new(&SystemConfig::tiny());
        let mut out = Vec::new();
        let mut t = 0u64;
        // Phase A: dense warm-up with random request arrivals.
        for _ in 0..200 {
            if rng.chance(0.4) {
                let line = (rng.next_u64() % (1 << 16)) & !127;
                let _ = p.request(t, line, rng.next_u64() & 0xFFFF, rng.chance(0.2), 8);
            }
            p.tick(t, &mut out, 4);
            out.clear();
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let snap = |p: &MemPartition| p.mc.reads + p.mc.writes + p.mc.row_hits + p.mc.row_misses;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "case {case}: no convergence");
            match p.next_event(t) {
                NextEvent::Idle => {
                    assert!(!p.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    p.tick(t, &mut out, 4);
                    out.clear();
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = snap(&p);
                        p.tick(t, &mut out, 4);
                        assert!(
                            out.is_empty() && snap(&p) == before,
                            "case {case}: partition acted at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Randomised tenant-conservation property over multi-tenant stream
/// runs: every CTA a tenant dispatches lands on a cluster inside its
/// partition, per-tenant attributed counters sum exactly to the chip
/// totals, and dispatched == retired == the trace's CTA count.
#[test]
fn prop_stream_tenant_conservation() {
    let names = ["CP", "BFS", "RAY", "SM", "LIB"];
    let schemes = Scheme::ALL;
    let mut rng = Pcg32::new(0x7E4A, 11);
    for case in 0..5 {
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters
        cfg.num_mcs = 4;
        cfg.max_cycles = 1_500_000;
        let n_tenants = 2 + rng.next_bounded(2) as usize; // 2..=3
        let tenants: Vec<_> = (0..n_tenants)
            .map(|_| {
                let p = bench(names[rng.next_bounded(names.len() as u32) as usize]).unwrap();
                let s = schemes[rng.next_bounded(schemes.len() as u32) as usize];
                (p, s)
            })
            .collect();
        let kernels_each = 1 + rng.next_bounded(2);
        let mean_gap = rng.next_bounded(5_000) as u64;
        let seed = rng.next_u64();
        let mut streams = traffic_trace(&tenants, kernels_each, mean_gap, seed);
        shrink_streams(&mut streams, 4, 40);
        let label = format!(
            "case {case}: {:?} x{kernels_each} gap {mean_gap} seed {seed:#x}",
            streams.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
        );

        let r = serve_streams(&cfg, &streams, PartitionPolicy::Static).unwrap();
        assert!(
            r.launches.iter().all(|l| l.finish != u64::MAX),
            "{label}: every launch served"
        );
        assert!(r.launches.iter().all(|l| l.start >= l.arrival), "{label}: causal starts");

        // Chip-total conservation of attributed counters.
        let ctas: u64 = r.tenants.iter().map(|t| t.sm.ctas_retired).sum();
        assert_eq!(ctas, r.sm.ctas_retired, "{label}: CTA attribution");
        let insns: u64 = r.tenants.iter().map(|t| t.sm.thread_insns).sum();
        assert_eq!(insns, r.sm.thread_insns, "{label}: insn attribution");
        let kernels: u64 = r.tenants.iter().map(|t| t.chip.kernels_completed).sum();
        assert_eq!(kernels, r.chip.kernels_completed, "{label}: kernel counts");

        // Placement: no CTA outside its tenant's (static) partition, and
        // per-tenant dispatched == retired == the trace's CTA count.
        for (ti, per_cluster) in r.ctas_by_cluster.iter().enumerate() {
            let dispatched: u64 = per_cluster.iter().sum();
            assert_eq!(dispatched, streams[ti].total_ctas(), "{label}: tenant {ti} dispatched");
            assert_eq!(
                dispatched, r.tenants[ti].sm.ctas_retired,
                "{label}: tenant {ti} dispatched == retired"
            );
            for (ci, &count) in per_cluster.iter().enumerate() {
                assert!(
                    count == 0 || r.partitions[ti].contains(&ci),
                    "{label}: tenant {ti} CTA on foreign cluster {ci}"
                );
            }
        }
        // Tenant finishes bound the chip clock.
        let last = r.tenants.iter().map(|t| t.cycles).max().unwrap();
        assert_eq!(last, r.cycles, "{label}: chip stops when the last tenant finishes");
    }
}

/// Priority-inversion regression over the partition-scoped drain: a
/// low-priority tenant's reconfigure (drain of its own clusters, then
/// the brief chip-wide request-gate quiesce) must not delay a
/// high-priority tenant's launch start at all — the start lands at
/// exactly the arrival cycle for *any* arrival inside the drain window.
/// The chip-global drain this replaced held every launch until the
/// whole machine went idle, which is exactly the inversion pinned here.
#[test]
fn prop_no_priority_inversion_across_partition_drain() {
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 8; // 4 clusters
    cfg.max_cycles = 1_500_000;
    let mut p0 = bench("CP").unwrap();
    p0.num_ctas = 4;
    p0.insns_per_thread = 40;
    // t1 (Low) adopts t0's freed fused cluster at its second launch
    // (cycle 500_000) and must drain + reconfigure it private; the
    // high-priority probe arrives at staggered offsets across that
    // window (just after the drain begins, mid-quiesce, well past it).
    for arrival in [500_010u64, 500_040, 500_400, 502_000] {
        let mut t0 = KernelStream::back_to_back("t0:CP", p0.clone(), Scheme::ScaleUp, 0xA01);
        t0.launches.truncate(1);
        t0.priority = Priority::Low;
        let mut t1 = KernelStream::back_to_back("t1:CP", p0.clone(), Scheme::Baseline, 0xA02);
        t1.launches.truncate(2);
        t1.launches[1].arrival = 500_000;
        t1.priority = Priority::Low;
        let mut p2 = bench("BFS").unwrap();
        p2.num_ctas = 12;
        p2.insns_per_thread = 800;
        let mut t2 = KernelStream::back_to_back("t2:BFS", p2, Scheme::Baseline, 0xA03);
        t2.launches.truncate(1);
        let mut t3 = KernelStream::back_to_back("t3:CP", p0.clone(), Scheme::Baseline, 0xA04);
        t3.launches.truncate(1);
        t3.launches[0].arrival = arrival;
        t3.priority = Priority::High;
        t3.slo_turnaround = Some(400_000);
        let streams = vec![t0, t1, t2, t3];

        let r = serve_streams(&cfg, &streams, PartitionPolicy::Adaptive).unwrap();
        assert!(!r.deadline_hit, "arrival {arrival}");
        assert!(
            r.launches.iter().all(|l| l.finish != u64::MAX),
            "arrival {arrival}: every launch served"
        );
        assert!(
            r.tenants[1].chip.reconfig_events >= 1,
            "arrival {arrival}: the low-priority tenant must actually reconfigure"
        );
        let l3 = r.launches.iter().find(|l| l.tenant == 3).unwrap();
        assert_eq!(
            l3.start, arrival,
            "arrival {arrival}: low-priority reconfigure delayed the high-priority start"
        );
        assert_eq!(l3.queue_delay, 0, "arrival {arrival}: queue_delay mirrors the start law");
        assert!(
            l3.turnaround() <= 400_000,
            "arrival {arrival}: the high tenant's tiny kernel must meet its SLO"
        );
    }
}

/// Horizon tightness for the multi-stream quiescence probe: a two-tenant
/// mini-chip (two clusters running *different* kernels, one shared NoC,
/// shared memory partitions — the components `Gpu::run_streams` folds
/// with `min_with`) is drained by walking promised horizons. Within a
/// promised window no cluster may make observable progress
/// ([`SmCluster::progress_probe`]), no packet may move, and no DRAM
/// access may be scheduled. (Earlier-than-needed horizons are allowed —
/// the loop just skips less.)
#[test]
fn prop_stream_quiescence_horizon_tightness() {
    let mut rng = Pcg32::new(0x5713, 12);
    for case in 0..8 {
        let cfg = SystemConfig::tiny(); // 2 clusters, 2 MCs
        let benches = ["CP", "BFS", "MUM", "RAY"];
        let pa = bench(benches[rng.next_bounded(4) as usize]).unwrap();
        let pb = bench(benches[rng.next_bounded(4) as usize]).unwrap();
        let seed_a = rng.next_u64();
        let seed_b = rng.next_u64();
        let mut shrink = |mut p: amoeba_gpu::workload::BenchProfile| {
            p.num_ctas = 2;
            p.insns_per_thread = 30 + rng.next_bounded(30);
            p
        };
        let (pa, pb) = (shrink(pa), shrink(pb));
        let ka = kernel_launches(&pa, seed_a)[0].clone();
        let kb = kernel_launches(&pb, seed_b)[0].clone();
        let gens = [TraceGen::new(&pa, &ka), TraceGen::new(&pb, &kb)];

        // Two private clusters: cluster 0 at nodes 0/1, cluster 1 at
        // nodes 2/3, MCs at nodes 4/5 (the all-private node map).
        let mut clusters =
            [SmCluster::new(0, &cfg, ClusterMode::PrivatePair), SmCluster::new(1, &cfg, ClusterMode::PrivatePair)];
        let nodes_of = [[0usize, 1], [2, 3]];
        let mut noc = Noc::with_nodes(&cfg, 4 + cfg.num_mcs);
        let mut partitions: Vec<MemPartition> =
            (0..cfg.num_mcs).map(|_| MemPartition::new(&cfg)).collect();
        let mut reply_retry: Vec<std::collections::VecDeque<amoeba_gpu::sim::mem::PartitionReply>> =
            (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect();
        let mut req_backlog: Vec<std::collections::VecDeque<Packet>> =
            (0..cfg.num_mcs).map(|_| std::collections::VecDeque::new()).collect();
        clusters[0].dispatch_cta(&ka, 0, &gens[0]);
        clusters[0].dispatch_cta(&ka, 1, &gens[0]);
        clusters[1].dispatch_cta(&kb, 0, &gens[1]);
        clusters[1].dispatch_cta(&kb, 1, &gens[1]);

        // One dense mini-chip cycle, mirroring `Gpu::tick` (requests into
        // partitions, replies back to the owning cluster).
        type RetryQ = Vec<std::collections::VecDeque<amoeba_gpu::sim::mem::PartitionReply>>;
        type BacklogQ = Vec<std::collections::VecDeque<Packet>>;
        let offer = |partitions: &mut Vec<MemPartition>, mc: usize, now: u64, pkt: &Packet| {
            let Payload::MemRequest { line, requester, is_write } = pkt.payload else {
                return true;
            };
            let tag = (pkt.src as u64) << 32 | requester as u64;
            partitions[mc].request(now, line, tag, is_write, cfg.l2_hit_latency as u64)
        };
        let mut tick = |now: u64,
                        clusters: &mut [SmCluster; 2],
                        noc: &mut Noc,
                        partitions: &mut Vec<MemPartition>,
                        reply_retry: &mut RetryQ,
                        req_backlog: &mut BacklogQ| {
            for ci in 0..2 {
                let gen = &gens[ci];
                clusters[ci].tick(now, noc, nodes_of[ci], gen);
            }
            noc.tick(now);
            for mc in 0..partitions.len() {
                let node = 4 + mc;
                // Retry the backlog first, then bounded new ejections —
                // the same discipline `Gpu::tick` applies.
                while let Some(pkt) = req_backlog[mc].front().copied() {
                    if offer(partitions, mc, now, &pkt) {
                        req_backlog[mc].pop_front();
                    } else {
                        break;
                    }
                }
                while req_backlog[mc].len() < 16 {
                    let Some(pkt) = noc.eject(Subnet::Request, node) else { break };
                    if !offer(partitions, mc, now, &pkt) {
                        req_backlog[mc].push_back(pkt);
                    }
                }
                let mut out = Vec::new();
                partitions[mc].tick(now, &mut out, 2);
                out.extend(reply_retry[mc].drain(..));
                for r in out {
                    let dst = (r.tag >> 32) as usize;
                    let flits =
                        if r.is_write { 1 } else { cfg.flits_for(cfg.line_bytes + 16) as u32 };
                    let pkt = Packet {
                        src: node,
                        dst,
                        flits,
                        born: now,
                        payload: Payload::MemReply {
                            line: r.line,
                            requester: (r.tag & 0xFFFF_FFFF) as u32,
                            is_write: r.is_write,
                        },
                    };
                    if !noc.inject(Subnet::Reply, pkt) {
                        reply_retry[mc].push_back(r);
                    }
                }
            }
            for node in 0..4 {
                while let Some(pkt) = noc.eject(Subnet::Reply, node) {
                    if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                        let ci = if node < 2 { 0 } else { 1 };
                        clusters[ci].on_reply(now, line, is_write);
                    }
                }
            }
        };

        let mut t = 0u64;
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(guard < 400_000, "case {case}: mini-chip never drained");
            let done = clusters.iter().all(|c| c.idle())
                && !noc.busy()
                && partitions.iter().all(|p| !p.busy())
                && reply_retry.iter().all(|q| q.is_empty())
                && req_backlog.iter().all(|q| q.is_empty());
            if done {
                break;
            }
            // The multi-stream quiescence probe: min over both tenants'
            // clusters and the shared components (what `Gpu::try_skip`
            // computes across tenants). Retry queues pending => live.
            let mut ev = NextEvent::Idle;
            for ci in 0..2 {
                ev = ev.min_with(clusters[ci].next_event(t, &gens[ci]));
            }
            ev = ev.min_with(noc.next_event(t));
            for p in &partitions {
                ev = ev.min_with(p.next_event(t));
            }
            if reply_retry.iter().any(|q| !q.is_empty())
                || req_backlog.iter().any(|q| !q.is_empty())
            {
                // Queued retries are serviced every cycle: live, exactly
                // as `Gpu::try_skip` treats them.
                ev = NextEvent::Progress;
            }
            match ev {
                NextEvent::Progress => {
                    tick(t, &mut clusters, &mut noc, &mut partitions, &mut reply_retry, &mut req_backlog);
                    t += 1;
                }
                NextEvent::Idle => {
                    panic!("case {case}: probe says Idle but the mini-chip is not drained");
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = (
                            clusters[0].progress_probe(),
                            clusters[1].progress_probe(),
                            noc.flits_routed,
                            noc.packets_delivered,
                            partitions
                                .iter()
                                .map(|p| p.mc.reads + p.mc.writes + p.mc.row_hits + p.mc.row_misses)
                                .sum::<u64>(),
                        );
                        tick(t, &mut clusters, &mut noc, &mut partitions, &mut reply_retry, &mut req_backlog);
                        let after = (
                            clusters[0].progress_probe(),
                            clusters[1].progress_probe(),
                            noc.flits_routed,
                            noc.packets_delivered,
                            partitions
                                .iter()
                                .map(|p| p.mc.reads + p.mc.writes + p.mc.row_hits + p.mc.row_misses)
                                .sum::<u64>(),
                        );
                        assert_eq!(
                            before, after,
                            "case {case}: observable progress at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
        // Both tenants ran to completion through the shared fabric.
        assert!(clusters[0].stats.thread_insns > 0 && clusters[1].stats.thread_insns > 0);
        assert_eq!(clusters[0].completed_ctas(), 2, "case {case}");
        assert_eq!(clusters[1].completed_ctas(), 2, "case {case}");
    }
}

/// Wake completeness at cluster granularity: a cluster driven with
/// per-component parking (don't tick inside a promised window; wake
/// eagerly on every event that can unblock it — reply packet, fill,
/// CTA dispatch — replaying the parked accounting in O(1) via
/// [`SmCluster::skip`]) must end bit-identical to a twin ticked densely
/// every cycle. A wake that arrives later than the cycle the component
/// can first make progress, or an incomplete accounting replay, makes
/// the twins diverge — in issue order, stall breakdown, or both.
///
/// Parking here uses *no* minimum-window threshold (unlike the GPU
/// loop's policy), so every promised horizon — even a one-cycle issue
/// port hold — exercises the park/wake machinery.
#[test]
fn prop_parked_cluster_wake_completeness() {
    let mut rng = Pcg32::new(0xAC71, 13);
    for case in 0..6 {
        let cfg = SystemConfig::tiny();
        let names = ["BFS", "CP", "RAY", "MUM"];
        let p = bench(names[rng.next_bounded(4) as usize]).unwrap();
        let mut p = p;
        p.num_ctas = 2;
        p.insns_per_thread = 40 + rng.next_bounded(40);
        let k = kernel_launches(&p, rng.next_u64())[0].clone();
        let gen = TraceGen::new(&p, &k);
        let latency = 20 + rng.next_bounded(60) as u64;
        let second_dispatch = 50 + rng.next_bounded(400) as u64;
        let label = format!("case {case}: {} lat {latency} disp2 @{second_dispatch}", p.name);

        // Twin A is ticked densely; twin B parks on every promised
        // horizon and is woken only by its timer or by events.
        let mk = || SmCluster::new(0, &cfg, ClusterMode::PrivatePair);
        let (mut dense, mut lazy) = (mk(), mk());
        // Nodes 0/1 = the cluster's halves, 2.. = MCs.
        let nodes = [0usize, 1];
        let n_nodes = 2 + cfg.num_mcs;
        let (mut noc_d, mut noc_l) = (Noc::with_nodes(&cfg, n_nodes), Noc::with_nodes(&cfg, n_nodes));
        dense.dispatch_cta(&k, 0, &gen);
        lazy.dispatch_cta(&k, 0, &gen);
        let mut dispatched = 1u32;

        // Scripted memory: every ejected request is answered after a
        // fixed latency (per twin, from its own noc).
        let mut mem_d: Vec<(u64, Packet)> = Vec::new();
        let mut mem_l: Vec<(u64, Packet)> = Vec::new();
        // Parked window of the lazy twin: (first unticked cycle, wake).
        let mut parked: Option<(u64, u64)> = None;

        let mut t = 0u64;
        loop {
            assert!(t < 400_000, "{label}: twins never drained");
            // Mid-run CTA dispatch: an external event that must wake a
            // parked cluster before it lands.
            if t == second_dispatch && dispatched < k.num_ctas {
                assert_eq!(
                    dense.can_accept_cta(&k),
                    lazy.can_accept_cta(&k),
                    "{label}: twins disagree on acceptance"
                );
                if dense.can_accept_cta(&k) {
                    dense.dispatch_cta(&k, dispatched, &gen);
                    if let Some((from, _)) = parked.take() {
                        lazy.skip(from, t - from);
                    }
                    lazy.dispatch_cta(&k, dispatched, &gen);
                    dispatched += 1;
                }
            }

            // Twin A: dense tick, always.
            dense.tick(t, &mut noc_d, nodes, &gen);
            // Twin B: tick only when live; park on any promise.
            if let Some((from, wake)) = parked {
                if t >= wake {
                    lazy.skip(from, t - from);
                    parked = None;
                }
            }
            if parked.is_none() {
                lazy.tick(t, &mut noc_l, nodes, &gen);
                parked = lazy.next_event(t + 1, &gen).wake_cycle().map(|w| (t + 1, w));
            }

            // Shared environment, per twin: NoC + scripted memory.
            for (noc, mem) in [(&mut noc_d, &mut mem_d), (&mut noc_l, &mut mem_l)] {
                noc.tick(t);
                for mc_node in 2..n_nodes {
                    while let Some(rq) = noc.eject(Subnet::Request, mc_node) {
                        if let Payload::MemRequest { line, requester, is_write } = rq.payload {
                            let reply = Packet {
                                src: mc_node,
                                dst: rq.src,
                                flits: if is_write { 1 } else { 9 },
                                born: t,
                                payload: Payload::MemReply { line, requester, is_write },
                            };
                            mem.push((t + latency, reply));
                        }
                    }
                }
                let mut i = 0;
                while i < mem.len() {
                    if mem[i].0 <= t && noc.inject(Subnet::Reply, mem[i].1) {
                        mem.swap_remove(i);
                    } else {
                        i += 1;
                    }
                }
            }
            // Reply delivery: an event wake for the parked twin, replayed
            // through this cycle (the dense loop ticked it pre-reply).
            for node in 0..2 {
                while let Some(pkt) = noc_d.eject(Subnet::Reply, node) {
                    if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                        dense.on_reply(t, line, is_write);
                    }
                }
                while let Some(pkt) = noc_l.eject(Subnet::Reply, node) {
                    if let Payload::MemReply { line, is_write, .. } = pkt.payload {
                        if let Some((from, _)) = parked.take() {
                            lazy.skip(from, (t + 1) - from);
                        }
                        lazy.on_reply(t, line, is_write);
                    }
                }
            }

            t += 1;
            let done = dispatched >= k.num_ctas.min(2)
                && dense.idle()
                && lazy.idle()
                && mem_d.is_empty()
                && mem_l.is_empty()
                && !noc_d.busy()
                && !noc_l.busy()
                && t > second_dispatch;
            if done {
                break;
            }
        }
        // Close the lazy twin's accounting at the stop cycle.
        if let Some((from, _)) = parked.take() {
            lazy.skip(from, t - from);
        }
        assert_eq!(
            dense.progress_probe(),
            lazy.progress_probe(),
            "{label}: observable progress diverged"
        );
        assert_eq!(dense.stats, lazy.stats, "{label}: stats diverged (late/missed wake)");
        assert_eq!(dense.completed_ctas(), lazy.completed_ctas(), "{label}");
        assert!(dense.stats.thread_insns > 0, "{label}: twin ran no work");
    }
}

/// Adversarial active-set regression, seeded from a Hetero +
/// DynSplit-active multi-tenant run: low split thresholds and short
/// check periods keep clusters splitting/re-fusing (external mutations
/// of parked-cluster state), a Hetero tenant exercises per-cluster
/// decisions on mixed layouts, and interleaved arrivals exercise
/// stream-arrival wakes. The active-set engine must stay bit-identical
/// to the dense loop through all of it.
#[test]
fn active_set_regression_hetero_dynsplit_streams() {
    for seed in [0xA5E7_0001u64, 0xA5E7_0002] {
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters
        cfg.num_mcs = 4;
        cfg.max_cycles = 1_500_000;
        cfg.split_threshold = 0.05;
        cfg.split_check_period = 128;
        cfg.rebalance_period = 256;
        let tenants = [
            (bench("RAY").unwrap(), Scheme::Hetero),
            (bench("RAY").unwrap(), Scheme::WarpRegroup),
            (bench("BFS").unwrap(), Scheme::Dws),
        ];
        let mut streams = traffic_trace(&tenants, 2, 3_000, seed);
        shrink_streams(&mut streams, 5, 60);
        for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
            let label = format!("seed {seed:#x} under {policy}");
            let dense = serve_streams_dense(&cfg, &streams, policy, true).unwrap();
            let active = serve_streams_dense(&cfg, &streams, policy, false).unwrap();
            assert!(
                dense.launches.iter().all(|l| l.finish != u64::MAX),
                "{label}: all launches served"
            );
            assert_eq!(dense, active, "{label}: active-set diverged from dense");
        }
    }
}

/// Randomised fault property: a whole-cluster death at a random cycle on
/// a chip with spare clusters conserves CTAs exactly — every dispatch is
/// balanced by a retirement or a requeue, every grid CTA retires exactly
/// once, and the kernel still completes (gracefully degraded, not lost).
#[test]
fn prop_faulted_run_conserves_ctas() {
    let names = ["CP", "BFS", "RAY", "SM"];
    let mut rng = Pcg32::new(0xFA17, 21);
    for case in 0..6 {
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters: losing one leaves capacity to finish
        cfg.num_mcs = 4;
        cfg.max_cycles = 1_500_000;
        let mut p = bench(names[rng.next_bounded(4) as usize]).unwrap();
        p.num_ctas = 6;
        p.insns_per_thread = 40 + rng.next_bounded(40);
        p.num_kernels = 1;
        let cluster = rng.next_bounded(4);
        let cycle = 1 + rng.next_bounded(5_000) as u64;
        let seed = rng.next_u64();
        let trace =
            FaultTrace::new(vec![FaultEvent { cycle, kind: FaultKind::Cluster { cluster } }]);
        let label = format!("case {case}: {} cluster {cluster} @{cycle} seed {seed:#x}", p.name);

        let r = run_benchmark_faulted(&cfg, &p, Scheme::Baseline, seed, &trace).unwrap();
        assert_eq!(r.chip.kernels_completed, 1, "{label}: survivors finish the kernel");
        assert!(!r.deadline_hit, "{label}: no truncation");
        assert_eq!(
            r.chip.ctas_dispatched,
            r.sm.ctas_retired + r.chip.ctas_requeued,
            "{label}: CTA conservation (dispatched == retired + requeued)"
        );
        assert_eq!(r.sm.ctas_retired, p.num_ctas as u64, "{label}: each grid CTA retires once");
        // The fault either landed (run outlived the injection cycle) and
        // retired the cluster, or the run finished first and did neither.
        assert_eq!(r.chip.clusters_retired, r.chip.faults_injected, "{label}");
        if r.chip.faults_injected == 0 {
            assert_eq!(r.chip.ctas_requeued, 0, "{label}: no fault, no requeues");
        }
    }
}

/// Randomised fault property: a cluster retired before the first dispatch
/// cycle never receives a CTA — the placement ledger's column for the
/// dead cluster stays zero for every tenant.
#[test]
fn prop_no_dispatch_to_retired_cluster() {
    let names = ["CP", "BFS", "RAY"];
    let mut rng = Pcg32::new(0xDEAD, 22);
    for case in 0..4 {
        let mut cfg = SystemConfig::tiny();
        cfg.num_sms = 8; // 4 clusters for 2 tenants
        cfg.num_mcs = 4;
        cfg.max_cycles = 1_500_000;
        let tenants: Vec<_> = (0..2)
            .map(|_| {
                (bench(names[rng.next_bounded(3) as usize]).unwrap(), Scheme::Baseline)
            })
            .collect();
        let mut streams = traffic_trace(&tenants, 1, 2_000, rng.next_u64());
        shrink_streams(&mut streams, 4, 40);
        let cluster = rng.next_bounded(4);
        // Injection at cycle 0 lands at the first loop top, before any
        // dispatch decision.
        let trace =
            FaultTrace::new(vec![FaultEvent { cycle: 0, kind: FaultKind::Cluster { cluster } }]);
        let label = format!("case {case}: retired cluster {cluster}");

        let r = serve_streams_faulted(&cfg, &streams, PartitionPolicy::Static, &trace).unwrap();
        assert_eq!(r.chip.faults_injected, 1, "{label}: fault lands");
        assert_eq!(r.chip.clusters_retired, 1, "{label}");
        assert_eq!(r.chip.ctas_requeued, 0, "{label}: nothing was in flight to requeue");
        for (ti, per_cluster) in r.ctas_by_cluster.iter().enumerate() {
            assert_eq!(
                per_cluster[cluster as usize], 0,
                "{label}: tenant {ti} dispatched to the retired cluster"
            );
        }
    }
}

/// Randomised fault property: attaching an **empty** fault trace is
/// bit-identical to running with no trace at all, across schemes and
/// seeds — the fault plumbing costs nothing when unused.
#[test]
fn prop_empty_fault_trace_is_bit_identical_to_none() {
    let names = ["CP", "BFS", "RAY", "MUM"];
    let mut rng = Pcg32::new(0x0FA1, 23);
    for case in 0..6 {
        let cfg = SystemConfig::tiny();
        let mut p = bench(names[rng.next_bounded(4) as usize]).unwrap();
        p.num_ctas = 4;
        p.insns_per_thread = 30 + rng.next_bounded(50);
        p.num_kernels = 1;
        let scheme = Scheme::ALL[rng.next_bounded(Scheme::ALL.len() as u32) as usize];
        let seed = rng.next_u64();
        let plain = run_benchmark_seeded(&cfg, &p, scheme, seed).unwrap();
        let empty = run_benchmark_faulted(&cfg, &p, scheme, seed, &FaultTrace::default()).unwrap();
        assert_eq!(plain, empty, "case {case}: {} under {scheme} seed {seed:#x}", p.name);
    }
}

/// Randomised wake-completeness property under intra-simulation
/// parallelism: for any profile / scheme / seed, fanning the active
/// cluster set across worker threads leaves every report bit-identical
/// to the serial walk, for every thread count. This is the contract the
/// per-cluster outbox design rests on — parked-window replay (which
/// clusters park, and when they wake) and NoC admission both depend
/// only on the fixed cluster-index merge order, never on which worker
/// ticked a cluster or when it finished.
#[test]
fn prop_tick_jobs_thread_count_invariance() {
    let names = ["CP", "BFS", "RAY", "MUM"];
    let mut rng = Pcg32::new(0x71C6, 24);
    for case in 0..5 {
        let cfg = SystemConfig::tiny();
        let mut p = bench(names[rng.next_bounded(4) as usize]).unwrap();
        p.num_ctas = 4 + rng.next_bounded(5);
        p.insns_per_thread = 30 + rng.next_bounded(60);
        p.num_kernels = 1;
        let scheme = Scheme::ALL[rng.next_bounded(Scheme::ALL.len() as u32) as usize];
        let seed = rng.next_u64();
        let serial = run_benchmark_seeded_jobs(&cfg, &p, scheme, seed, false, 1).unwrap();
        for jobs in [2usize, 3] {
            let fanned = run_benchmark_seeded_jobs(&cfg, &p, scheme, seed, false, jobs).unwrap();
            assert_eq!(
                serial, fanned,
                "case {case}: {} under {scheme} seed {seed:#x} diverged at {jobs} tick jobs",
                p.name
            );
        }
    }
    // Multi-tenant serving parks and wakes clusters far more often than a
    // single benchmark run — the replayed wake windows must also be
    // thread-count-invariant.
    let tenants =
        vec![(bench("BFS").unwrap(), Scheme::Hetero), (bench("RAY").unwrap(), Scheme::Baseline)];
    let mut cfg = SystemConfig::tiny();
    cfg.num_sms = 8;
    cfg.num_mcs = 4;
    cfg.max_cycles = 1_500_000;
    let mut streams = traffic_trace(&tenants, 2, 3_000, rng.next_u64());
    shrink_streams(&mut streams, 5, 60);
    for policy in [PartitionPolicy::Static, PartitionPolicy::Adaptive] {
        let serial = serve_streams_jobs(&cfg, &streams, policy, false, 1).unwrap();
        for jobs in [2usize, 3] {
            let fanned = serve_streams_jobs(&cfg, &streams, policy, false, jobs).unwrap();
            assert_eq!(serial, fanned, "{policy:?} streams diverged at {jobs} tick jobs");
        }
    }
}

/// A real checkpoint captured mid-run. Small profile, early capture:
/// the fuzz loops below parse every byte prefix, so the byte count is
/// the iteration count.
fn fuzz_checkpoint() -> amoeba_gpu::sim::Checkpoint {
    let cfg = SystemConfig::tiny();
    let mut p = bench("CP").unwrap();
    p.num_ctas = 4;
    p.insns_per_thread = 40;
    p.num_kernels = 1;
    let (_, cp) =
        amoeba_gpu::sim::gpu::run_benchmark_snapshot(&cfg, &p, Scheme::Baseline, 0xF2, false, 30, None)
            .unwrap();
    cp.expect("snapshot at cycle 30 must fire")
}

/// Byte-exact checkpoint round trip: parsing a serialized checkpoint
/// and re-serializing it reproduces the input bytes exactly — section
/// order, names, and payloads all survive (`save(load(x)) == x`), and
/// the parsed container compares equal to the original.
#[test]
fn prop_checkpoint_bytes_round_trip() {
    use amoeba_gpu::sim::Checkpoint;
    let cp = fuzz_checkpoint();
    let bytes = cp.to_bytes();
    let parsed = Checkpoint::from_bytes(&bytes).unwrap();
    assert_eq!(parsed, cp, "parsed checkpoint differs from the captured one");
    assert_eq!(parsed.to_bytes(), bytes, "re-serialization is not byte-identical");
    assert_eq!(cp.byte_len(), bytes.len());
    // The file path round-trips through the same bytes.
    let path = std::env::temp_dir().join(format!("amoeba-cp-fuzz-{}.bin", std::process::id()));
    cp.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded, cp, "file round trip changed the checkpoint");
    let _ = std::fs::remove_file(&path);
}

/// Truncation fuzz: every strict byte prefix of a valid checkpoint must
/// parse to a structured error — never a panic, and never a silent
/// partial success. The same holds for a handful of random single-byte
/// corruptions at the container level (they may parse, since payload
/// bytes are opaque to the container, but they must never panic).
#[test]
fn prop_checkpoint_truncation_never_panics() {
    use amoeba_gpu::sim::Checkpoint;
    let cp = fuzz_checkpoint();
    let bytes = cp.to_bytes();
    for n in 0..bytes.len() {
        assert!(
            Checkpoint::from_bytes(&bytes[..n]).is_err(),
            "strict prefix of {n}/{} bytes parsed as a whole checkpoint",
            bytes.len()
        );
    }
    assert!(Checkpoint::from_bytes(&bytes).is_ok());
    let mut rng = Pcg32::new(0xC4A0, 9);
    for _ in 0..200 {
        let mut corrupt = bytes.clone();
        let i = rng.next_bounded(corrupt.len() as u32) as usize;
        corrupt[i] ^= (1 + rng.next_bounded(255)) as u8;
        let _ = Checkpoint::from_bytes(&corrupt); // must not panic
    }
}

/// Section-level restore fuzz: truncating any one section's payload (to
/// half, to one byte, to empty) must make the restore entry point return
/// a structured error — the machine loaders validate shape and length
/// before touching state, so corrupt state never restores partially.
#[test]
fn prop_checkpoint_section_truncation_is_an_error() {
    let cfg = SystemConfig::tiny();
    let mut p = bench("CP").unwrap();
    p.num_ctas = 4;
    p.insns_per_thread = 40;
    p.num_kernels = 1;
    let cp = fuzz_checkpoint();
    let resume = |c: &amoeba_gpu::sim::Checkpoint| {
        amoeba_gpu::sim::gpu::run_benchmark_resume(&cfg, &p, Scheme::Baseline, 0xF2, false, c)
    };
    assert!(resume(&cp).is_ok(), "the untouched checkpoint must restore");
    for si in 0..cp.sections.len() {
        let full_len = cp.sections[si].bytes.len();
        for keep in [full_len / 2, 1.min(full_len), 0] {
            if keep >= full_len {
                continue;
            }
            let mut broken = cp.clone();
            broken.sections[si].bytes.truncate(keep);
            let name = &cp.sections[si].name;
            assert!(
                resume(&broken).is_err(),
                "section '{name}' truncated to {keep}/{full_len} bytes restored anyway"
            );
        }
        // Dropping the section entirely is an error too.
        let mut missing = cp.clone();
        missing.sections.remove(si);
        assert!(
            resume(&missing).is_err(),
            "checkpoint without section '{}' restored anyway",
            cp.sections[si].name
        );
    }
}

/// The disk-memo parsers obey the same contract: every strict byte
/// prefix of a valid spill file is a structured error, never a panic —
/// for both the single-application and the stream flavor.
#[test]
fn prop_memo_truncation_never_panics() {
    use amoeba_gpu::harness::{parse_sim_memo, parse_stream_memo, SweepExec};
    let dir = std::env::temp_dir().join(format!("amoeba-memo-fuzz-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let exec = SweepExec::new(1).with_disk_memo(&dir);

    let cfg = SystemConfig::tiny();
    let mut p = bench("CP").unwrap();
    p.num_ctas = 4;
    p.insns_per_thread = 40;
    p.num_kernels = 1;
    let job = amoeba_gpu::harness::SimJob::new(cfg.clone(), p, Scheme::Baseline, 5);
    exec.run(&job.cfg, &job.profile, job.scheme, job.seed);

    let tenants = vec![(bench("CP").unwrap(), Scheme::Baseline)];
    let mut streams = traffic_trace(&tenants, 1, 0, 3);
    shrink_streams(&mut streams, 4, 40);
    let sjob =
        amoeba_gpu::harness::StreamJob::new(cfg, streams, PartitionPolicy::Static);
    exec.run_stream(&sjob);

    let mut fuzzed = (0usize, 0usize);
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        let bytes = std::fs::read(&path).unwrap();
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        if name.starts_with("sim-") {
            for n in 0..bytes.len() {
                assert!(parse_sim_memo(&bytes[..n], &job.key()).is_err(), "{name} prefix {n}");
            }
            assert!(parse_sim_memo(&bytes, &job.key()).is_ok(), "{name}: full file parses");
            // A stale key is an error even on intact bytes.
            let mut other = job.key();
            other.seed ^= 1;
            assert!(parse_sim_memo(&bytes, &other).is_err(), "{name}: stale key accepted");
            fuzzed.0 += 1;
        } else if name.starts_with("stream-") {
            for n in 0..bytes.len() {
                assert!(parse_stream_memo(&bytes[..n], &sjob.key()).is_err(), "{name} prefix {n}");
            }
            assert!(parse_stream_memo(&bytes, &sjob.key()).is_ok(), "{name}: full file parses");
            fuzzed.1 += 1;
        }
    }
    assert_eq!(fuzzed, (1, 1), "expected exactly one spill file of each kind");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fleet conservation under randomized pools, tenant mixes, and chip
/// losses: every launch in the trace is served exactly once or honestly
/// rejected/dropped — never double-served, never silently lost. The
/// per-tenant ledgers roll up to the fleet totals exactly, no tenant is
/// resident on two chips, and a migrated-in tenant always arrives from a
/// non-healthy source chip onto a different, healthy one.
#[test]
fn prop_fleet_conservation() {
    use amoeba_gpu::harness::SweepExec;
    use amoeba_gpu::runtime::fleet::{serve_fleet, ChipHealth, FleetConfig};
    let exec = SweepExec::new(2);
    let mut rng = Pcg32::new(0xF1EE7, 11);
    let names = ["CP", "BFS", "SM"];
    for case in 0u64..5 {
        let pool = 1 + rng.next_bounded(3) as usize;
        let n_tenants = 2 + rng.next_bounded(4) as usize;
        let mut chip = SystemConfig::tiny();
        chip.max_cycles = 300_000;
        let mut fc = FleetConfig::pool(chip, pool);
        fc.tenants_per_chip = 1 + rng.next_bounded(2) as usize;
        let tenants: Vec<_> = (0..n_tenants)
            .map(|i| (bench(names[i % names.len()]).unwrap(), Scheme::Baseline))
            .collect();
        let gap = 2_000 + rng.next_bounded(8_000) as u64;
        let mut streams = traffic_trace(&tenants, 2, gap, 0xD37 + case);
        shrink_streams(&mut streams, 4, 40);
        // Half the cases lose one random chip outright at cycle 10.
        let mut faults = vec![FaultTrace::default(); pool];
        if rng.chance(0.5) {
            let victim = rng.next_bounded(pool as u32) as usize;
            faults[victim] = FaultTrace::new(vec![
                FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 0 } },
                FaultEvent { cycle: 10, kind: FaultKind::Cluster { cluster: 1 } },
            ]);
        }
        let rep = serve_fleet(&exec, &fc, &streams, &faults)
            .unwrap_or_else(|e| panic!("case {case}: serve_fleet failed: {e}"));

        // Fleet-level conservation.
        let total: u32 = streams.iter().map(|s| s.launches.len() as u32).sum();
        assert_eq!(
            rep.served + rep.dropped + rep.rejected_launches,
            total,
            "case {case}: fleet conservation"
        );

        // Per-tenant ledgers roll up to the fleet totals exactly.
        let (mut served, mut dropped, mut rejected) = (0u32, 0u32, 0u32);
        for ft in &rep.tenants {
            let launches = streams[ft.tenant].launches.len() as u32;
            if ft.rejected.is_some() {
                assert!(ft.chip.is_none(), "case {case}: rejected tenant {} holds a chip", ft.tenant);
                assert_eq!(
                    ft.served + ft.dropped,
                    0,
                    "case {case}: rejected tenant {} ran anyway",
                    ft.tenant
                );
                rejected += launches;
            } else {
                assert!(ft.chip.is_some(), "case {case}: admitted tenant {} has no chip", ft.tenant);
                assert_eq!(
                    ft.served + ft.dropped,
                    launches,
                    "case {case}: tenant {} conservation",
                    ft.tenant
                );
            }
            served += ft.served;
            dropped += ft.dropped;
        }
        assert_eq!(served, rep.served, "case {case}: served roll-up");
        assert_eq!(dropped, rep.dropped, "case {case}: dropped roll-up");
        assert_eq!(rejected, rep.rejected_launches, "case {case}: rejected-launch roll-up");
        assert_eq!(
            rep.tenants.iter().filter(|t| t.rejected.is_some()).count() as u32,
            rep.rejections,
            "case {case}: rejection count"
        );
        assert_eq!(
            rep.tenants.iter().filter(|t| t.migrated_to.is_some()).count() as u32,
            rep.migrations,
            "case {case}: migration count"
        );

        // Residency: every admitted tenant lives on exactly one chip, and
        // a migrated-in tenant arrives from a non-healthy source onto a
        // different, healthy destination.
        let mut seen = vec![0usize; streams.len()];
        for c in &rep.chips {
            for &ti in &c.tenants {
                seen[ti] += 1;
                assert_eq!(
                    rep.tenants[ti].chip,
                    Some(c.chip),
                    "case {case}: tenant {ti} listed on a chip that is not its home"
                );
            }
            for &ti in &c.migrated_in {
                let src = rep.tenants[ti].chip.expect("migrated tenant was admitted");
                assert_ne!(src, c.chip, "case {case}: tenant {ti} migrated onto its own chip");
                assert_eq!(
                    rep.tenants[ti].migrated_to,
                    Some(c.chip),
                    "case {case}: migrated_in/migrated_to disagree for tenant {ti}"
                );
                assert_ne!(
                    rep.chips[src].health,
                    ChipHealth::Healthy,
                    "case {case}: tenant {ti} migrated off a healthy chip"
                );
                assert_eq!(
                    c.health,
                    ChipHealth::Healthy,
                    "case {case}: tenant {ti} migrated onto a non-healthy chip"
                );
            }
        }
        for (ti, ft) in rep.tenants.iter().enumerate() {
            let expected = usize::from(ft.rejected.is_none());
            assert_eq!(
                seen[ti], expected,
                "case {case}: tenant {ti} resident on {} chips",
                seen[ti]
            );
        }
    }
}

/// Active-mask algebra invariants under random masks.
#[test]
fn prop_mask_algebra() {
    let mut rng = Pcg32::new(0x3A5C, 6);
    for _ in 0..1000 {
        let m = ActiveMask(rng.next_u64());
        let full = ActiveMask::full(64);
        assert_eq!((m & full).0, m.0);
        assert_eq!((m | ActiveMask::empty()).0, m.0);
        assert_eq!(m.low_half(64).count() + m.high_half(64).count(), m.count());
        let m32 = ActiveMask(m.0 & ActiveMask::full(32).0);
        assert_eq!(m32.low_half(32).count() + m32.high_half(32).count(), m32.count());
        assert_eq!(m.lanes().count() as u32, m.count());
    }
}
