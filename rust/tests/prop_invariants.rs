//! Property-based tests over the coordinator's core invariants
//! (randomised with the in-repo PCG RNG; proptest is not available in the
//! offline vendored registry, so shrinking is replaced by printing the
//! failing seed — rerun with that seed to reproduce).

use amoeba_gpu::config::SystemConfig;
use amoeba_gpu::isa::{AccessPattern, ActiveMask};
use amoeba_gpu::sim::mem::{
    coalesce, coalesce_fused, Access, Cache, DramRequest, MemPartition, MemoryController,
};
use amoeba_gpu::sim::noc::{Noc, Packet, Payload, Subnet};
use amoeba_gpu::sim::NextEvent;
use amoeba_gpu::workload::Pcg32;

/// Randomised property: coalescing never produces more transactions than
/// active lanes, never zero for a non-empty mask, and is deterministic.
#[test]
fn prop_coalesce_bounds() {
    let mut rng = Pcg32::new(0xC0A1, 1);
    for case in 0..500 {
        let width = [8usize, 16, 32][rng.next_bounded(3) as usize];
        let mask = ActiveMask(rng.next_u64() & ActiveMask::full(width).0);
        let pattern = match rng.next_bounded(3) {
            0 => AccessPattern::Strided {
                base: rng.next_u64() % (1 << 30),
                stride: [4u32, 8, 64, 256][rng.next_bounded(4) as usize],
            },
            1 => AccessPattern::Broadcast { base: rng.next_u64() % (1 << 30) },
            _ => AccessPattern::Scatter { base: 0, seed: rng.next_u64() },
        };
        let r = coalesce(&pattern, mask, width, 128);
        let active = mask.lanes().take_while(|&l| l < width).count();
        assert!(r.transactions() <= active.max(1), "case {case}: txns > lanes");
        assert_eq!(r.requests as usize, active, "case {case}");
        if active > 0 {
            assert!(r.transactions() >= 1, "case {case}");
        }
        let r2 = coalesce(&pattern, mask, width, 128);
        assert_eq!(r.lines, r2.lines, "case {case}: nondeterministic");
        // Every line is line-aligned.
        assert!(r.lines.iter().all(|l| l % 128 == 0), "case {case}");
    }
}

/// Fused coalescing never produces more transactions than running the two
/// sub-warps through separate coalescers (the paper's Fig 4 direction).
#[test]
fn prop_fused_coalescing_never_worse() {
    let mut rng = Pcg32::new(0xF00D, 2);
    for case in 0..500 {
        let mk = |rng: &mut Pcg32| match rng.next_bounded(3) {
            0 => AccessPattern::Strided {
                base: rng.next_u64() % (1 << 24),
                stride: [4u32, 16, 128][rng.next_bounded(3) as usize],
            },
            1 => AccessPattern::Broadcast { base: rng.next_u64() % (1 << 24) },
            _ => AccessPattern::Scatter { base: 0, seed: rng.next_u64() },
        };
        let (a, b) = (mk(&mut rng), mk(&mut rng));
        let fused = coalesce_fused(&a, &b, ActiveMask::full(64), 128);
        let sep =
            coalesce(&a, ActiveMask::full(32), 32, 128).transactions()
                + coalesce(&b, ActiveMask::full(32), 32, 128).transactions();
        assert!(
            fused.transactions() <= sep,
            "case {case}: fused {} > separate {sep}",
            fused.transactions()
        );
    }
}

/// Cache invariant: every MissNew is eventually balanced by exactly one
/// fill, MSHR occupancy never exceeds capacity, and a filled line hits.
#[test]
fn prop_cache_mshr_balance() {
    let mut rng = Pcg32::new(0xCACE, 3);
    for case in 0..100 {
        let mshrs = 1 + rng.next_bounded(16) as usize;
        let mut cache = Cache::new(4096, 2, 128, 1, mshrs);
        let mut outstanding: Vec<u64> = Vec::new();
        for _ in 0..400 {
            if rng.chance(0.6) || outstanding.is_empty() {
                let addr = (rng.next_u64() % (1 << 16)) & !127;
                match cache.access(addr) {
                    Access::MissNew => outstanding.push(addr),
                    Access::MshrFull => {
                        assert_eq!(
                            cache.mshrs_in_flight(),
                            mshrs,
                            "case {case}: MshrFull below capacity"
                        );
                    }
                    Access::Hit | Access::MissMerged => {}
                }
            } else {
                let i = rng.next_bounded(outstanding.len() as u32) as usize;
                let addr = outstanding.swap_remove(i);
                let released = cache.fill(addr);
                assert!(released >= 1, "case {case}: fill released nothing");
                assert_eq!(cache.access(addr), Access::Hit, "case {case}: fill not resident");
            }
            assert!(cache.mshrs_in_flight() <= mshrs, "case {case}: MSHR overflow");
        }
        // Drain.
        for addr in outstanding.drain(..) {
            cache.fill(addr);
        }
        assert_eq!(cache.mshrs_in_flight(), 0, "case {case}: leaked MSHRs");
    }
}

/// NoC conservation: every injected packet is ejected exactly once at its
/// destination, regardless of load pattern.
#[test]
fn prop_noc_conservation() {
    let mut rng = Pcg32::new(0x0C0C, 4);
    for case in 0..30 {
        let cfg = SystemConfig::tiny();
        let nodes = 4 + rng.next_bounded(12) as usize;
        let mut noc = Noc::with_nodes(&cfg, nodes);
        let mut sent = vec![0u32; nodes];
        let mut got = vec![0u32; nodes];
        let mut t = 0u64;
        let total_offers = 200 + rng.next_bounded(300);
        let mut offered = 0;
        while t < 20_000 {
            if offered < total_offers {
                let src = rng.next_bounded(nodes as u32) as usize;
                let dst = rng.next_bounded(nodes as u32) as usize;
                let pkt = Packet {
                    src,
                    dst,
                    flits: 1 + rng.next_bounded(5),
                    born: t,
                    payload: Payload::MemRequest { line: 0, requester: 0, is_write: false },
                };
                if noc.inject(Subnet::Request, pkt) {
                    sent[dst] += 1;
                    offered += 1;
                }
            }
            noc.tick(t);
            for n in 0..nodes {
                while noc.eject(Subnet::Request, n).is_some() {
                    got[n] += 1;
                }
            }
            if offered >= total_offers && !noc.busy() {
                break;
            }
            t += 1;
        }
        assert_eq!(sent, got, "case {case}: packet conservation violated");
        assert!(!noc.busy(), "case {case}: packets stranded");
    }
}

/// FR-FCFS conservation: every accepted DRAM request is answered once.
#[test]
fn prop_dram_conservation() {
    let mut rng = Pcg32::new(0xD3A3, 5);
    for case in 0..50 {
        let mut mc = MemoryController::new(
            1 + rng.next_bounded(8) as usize,
            2048,
            40,
            110,
            4 + rng.next_bounded(28) as usize,
        );
        let mut accepted = 0u32;
        let mut answered = 0u32;
        let mut tags = std::collections::HashSet::new();
        let mut t = 0u64;
        while t < 60_000 {
            if rng.chance(0.4) && accepted < 300 {
                let req = amoeba_gpu::sim::mem::DramRequest {
                    addr: (rng.next_u64() % (1 << 20)) & !127,
                    is_write: rng.chance(0.3),
                    tag: accepted as u64,
                };
                if mc.push(req) {
                    accepted += 1;
                }
            }
            mc.tick(t);
            while let Some(r) = mc.pop_reply() {
                answered += 1;
                assert!(tags.insert(r.tag), "case {case}: duplicate reply tag {}", r.tag);
            }
            if accepted >= 300 && !mc.busy() {
                break;
            }
            t += 1;
        }
        assert_eq!(accepted, answered, "case {case}: dram lost/duplicated requests");
    }
}

/// Event-horizon tightness, DRAM side: `next_event` must never promise a
/// horizon later than the first observable state change the dense tick
/// loop would make. (Earlier is allowed — the loop just skips less.)
#[test]
fn prop_mc_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x3E47, 7);
    for case in 0..40 {
        let mut mc = MemoryController::new(
            1 + rng.next_bounded(8) as usize,
            2048,
            40,
            110,
            4 + rng.next_bounded(28) as usize,
        );
        // Phase A: dense warm-up with random arrivals (promises are only
        // checked in windows with no external input, since a push can
        // legitimately create activity inside a previously-quiet window).
        let mut tag = 0u64;
        let mut t = 0u64;
        for _ in 0..150 {
            if rng.chance(0.5) {
                let _ = mc.push(DramRequest {
                    addr: (rng.next_u64() % (1 << 20)) & !127,
                    is_write: rng.chance(0.3),
                    tag: { tag += 1; tag },
                });
            }
            mc.tick(t);
            while mc.pop_reply().is_some() {}
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let snap = |m: &MemoryController| m.reads + m.writes + m.row_hits + m.row_misses;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "case {case}: no convergence");
            match mc.next_event(t) {
                NextEvent::Idle => {
                    assert!(!mc.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    mc.tick(t);
                    while mc.pop_reply().is_some() {}
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = snap(&mc);
                        mc.tick(t);
                        let mut popped = 0;
                        while mc.pop_reply().is_some() {
                            popped += 1;
                        }
                        assert!(
                            snap(&mc) == before && popped == 0,
                            "case {case}: state changed at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Event-horizon tightness, NoC side: within a promised window no packet
/// may move (no flits routed, nothing delivered or ejectable).
#[test]
fn prop_noc_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x90C7, 8);
    for case in 0..30 {
        let cfg = SystemConfig::tiny();
        let nodes = 4 + rng.next_bounded(12) as usize;
        let mut noc = Noc::with_nodes(&cfg, nodes);
        let mut t = 0u64;
        // Phase A: dense warm-up under random load.
        for _ in 0..100 {
            if rng.chance(0.6) {
                let src = rng.next_bounded(nodes as u32) as usize;
                let dst = rng.next_bounded(nodes as u32) as usize;
                let _ = noc.inject(
                    Subnet::Request,
                    Packet {
                        src,
                        dst,
                        flits: 1 + rng.next_bounded(5),
                        born: t,
                        payload: Payload::MemRequest { line: 0, requester: 0, is_write: false },
                    },
                );
            }
            noc.tick(t);
            for n in 0..nodes {
                while noc.eject(Subnet::Request, n).is_some() {}
            }
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 20_000, "case {case}: no convergence");
            match noc.next_event(t) {
                NextEvent::Idle => {
                    assert!(!noc.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    noc.tick(t);
                    for n in 0..nodes {
                        while noc.eject(Subnet::Request, n).is_some() {}
                    }
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = (noc.flits_routed, noc.packets_delivered);
                        noc.tick(t);
                        assert_eq!(
                            (noc.flits_routed, noc.packets_delivered),
                            before,
                            "case {case}: packet moved at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Event-horizon tightness, memory-partition side (L2 hit pipeline +
/// DRAM behind it): within a promised window the partition emits no
/// reply and schedules no DRAM access.
#[test]
fn prop_partition_next_event_never_later_than_first_change() {
    let mut rng = Pcg32::new(0x9A47, 9);
    for case in 0..30 {
        let mut p = MemPartition::new(&SystemConfig::tiny());
        let mut out = Vec::new();
        let mut t = 0u64;
        // Phase A: dense warm-up with random request arrivals.
        for _ in 0..200 {
            if rng.chance(0.4) {
                let line = (rng.next_u64() % (1 << 16)) & !127;
                let _ = p.request(t, line, rng.next_u64() & 0xFFFF, rng.chance(0.2), 8);
            }
            p.tick(t, &mut out, 4);
            out.clear();
            t += 1;
        }
        // Phase B: drain, walking the promised horizons.
        let snap = |p: &MemPartition| p.mc.reads + p.mc.writes + p.mc.row_hits + p.mc.row_misses;
        let mut guard = 0;
        loop {
            guard += 1;
            assert!(guard < 10_000, "case {case}: no convergence");
            match p.next_event(t) {
                NextEvent::Idle => {
                    assert!(!p.busy(), "case {case}: Idle while busy");
                    break;
                }
                NextEvent::Progress => {
                    p.tick(t, &mut out, 4);
                    out.clear();
                    t += 1;
                }
                NextEvent::At(h) => {
                    assert!(h > t, "case {case}: horizon {h} not in the future of {t}");
                    while t < h {
                        let before = snap(&p);
                        p.tick(t, &mut out, 4);
                        assert!(
                            out.is_empty() && snap(&p) == before,
                            "case {case}: partition acted at {t}, before promised horizon {h}"
                        );
                        t += 1;
                    }
                }
            }
        }
    }
}

/// Active-mask algebra invariants under random masks.
#[test]
fn prop_mask_algebra() {
    let mut rng = Pcg32::new(0x3A5C, 6);
    for _ in 0..1000 {
        let m = ActiveMask(rng.next_u64());
        let full = ActiveMask::full(64);
        assert_eq!((m & full).0, m.0);
        assert_eq!((m | ActiveMask::empty()).0, m.0);
        assert_eq!(m.low_half(64).count() + m.high_half(64).count(), m.count());
        let m32 = ActiveMask(m.0 & ActiveMask::full(32).0);
        assert_eq!(m32.low_half(32).count() + m32.high_half(32).count(), m32.count());
        assert_eq!(m.lanes().count() as u32, m.count());
    }
}
